//! Integration tests for the GEMM coordinator.  Most require real PJRT
//! artifacts (`make artifacts`) and skip without them; the engine-lane
//! tests at the bottom inject an *empty* manifest instead — no artifact
//! can serve anything there, which is exactly the regime the cached-plan
//! bucketed engine lane exists for — so they run everywhere.

use std::time::{Duration, Instant};

use tensoremu::coordinator::request::ServedBy;
use tensoremu::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, CoordinatorError, GemmRequest, PrecisionMode,
};
use tensoremu::formats::Scale;
use tensoremu::gemm::{
    bf16_gemm_scalar, fp8_gemm_scalar, int8_gemm_scalar, mixed_gemm, sparse24_gemm_scalar,
    tf32_gemm_scalar, Matrix,
};
use tensoremu::precision::{refine_gemm, RefineMode};
use tensoremu::runtime::{is_artifacts_missing, ExecutorServer, Manifest};
use tensoremu::workload::{uniform_matrix, Rng};

/// Skips (returns None) when the PJRT artifacts are not built — the
/// coordinator cannot start without a manifest.  Only that case skips;
/// any other startup failure panics so regressions stay visible.
fn coordinator_cfg(cfg: CoordinatorConfig) -> Option<Coordinator> {
    match Coordinator::start(cfg) {
        Ok(c) => Some(c),
        Err(e) if is_artifacts_missing(&e) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
        Err(e) => panic!("coordinator startup failed (not a missing build): {e:#}"),
    }
}

fn coordinator() -> Option<Coordinator> {
    coordinator_cfg(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(3),
            ..Default::default()
        },
        ..Default::default()
    })
}

#[test]
fn serves_a_large_gemm_on_tensor_core_path() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(1);
    let a = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::TensorCore);
    assert_eq!(resp.mode, RefineMode::None);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn batches_tile_requests_together() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(2);
    // submit a burst of 16x16 requests, then collect
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..40 {
        let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(0, a.clone(), b.clone())));
        inputs.push((a, b));
    }
    for (rx, (a, b)) in rxs.into_iter().zip(inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedTensorCore);
        let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.responses, 40);
    assert_eq!(snap.batched, 40);
    assert!(snap.flushes >= 1, "expected at least one flush");
    assert!(
        snap.flushes < 40,
        "requests must be batched, not served one-by-one (flushes = {})",
        snap.flushes
    );
    c.shutdown();
}

#[test]
fn error_budget_selects_refined_artifact() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(3);
    let a = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_error_budget(1e-7))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineAB);
    let want = refine_gemm(&a, &b, RefineMode::RefineAB);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn explicit_mode_respected() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(4);
    let a = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_mode(RefineMode::RefineA))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineA);
    let want = refine_gemm(&a, &b, RefineMode::RefineA);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn odd_shapes_served_by_cpu_fallback() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(5);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::CpuFallback);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-5);
    assert_eq!(c.metrics_snapshot().fallback, 1);
    c.shutdown();
}

#[test]
fn mixed_traffic_all_served_correctly() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(6);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..30 {
        let n = match i % 3 {
            0 => 16,
            1 => 64,
            _ => 128,
        };
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(mixed_gemm(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.responses, 30);
    assert!(snap.batched == 10 && snap.direct == 20, "{}", snap.report());
    c.shutdown();
}

#[test]
fn response_ids_match_requests() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(7);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let rx = c.submit(GemmRequest::new(4242, a, b));
    let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(resp.id, 4242);
    c.shutdown();
}

#[test]
fn latency_accounting_present() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(8);
    let a = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let resp = c.gemm(a, b).unwrap();
    assert!(resp.exec > Duration::ZERO);
    let snap = c.metrics_snapshot();
    assert!(snap.p50 > Duration::ZERO);
    c.shutdown();
}

/// A coordinator over an *empty* manifest: no batched artifact, no
/// direct artifacts — every square request must ride the bucketed
/// engine lane, and only non-square requests may fall back.  Needs no
/// built artifacts, so it runs on every machine.
fn engine_only_coordinator_cfg(cfg: CoordinatorConfig) -> Coordinator {
    let manifest = Manifest { dir: std::path::PathBuf::from("unbuilt"), artifacts: Vec::new() };
    let executor = ExecutorServer::start(manifest).expect("executor over empty manifest");
    Coordinator::start_with(cfg, executor).expect("coordinator over empty manifest")
}

fn engine_only_coordinator() -> Coordinator {
    engine_only_coordinator_cfg(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
        ..Default::default()
    })
}

/// A config whose batchers can never flush on their own during a test
/// (huge timers, huge capacity): whatever is admitted stays queued until
/// shutdown — the deterministic, sleep-free setup for the shed and
/// shutdown totality sweeps.
fn never_flush_cfg(queue_cap: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        queue_cap,
        batcher: BatcherConfig {
            max_batch: 100_000,
            max_wait: Duration::from_secs(100_000),
            deadline_slack: Duration::from_millis(1),
        },
        ..Default::default()
    }
}

#[test]
fn square_non_tile_requests_ride_engine_lane_with_zero_fallbacks() {
    // the acceptance check for the PR 2 open item: a square non-tile
    // workload keeps the CPU-fallback counter at exactly zero and is
    // served bitwise-correctly through cached per-edge plans
    let c = engine_only_coordinator();
    let mut rng = Rng::new(11);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..24u64 {
        let n = [24usize, 48, 33][(i % 3) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(mixed_gemm(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, RefineMode::None);
        // the engine lane is the host engine: bitwise equal to the oracle
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "square requests must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 24, "{}", snap.report());
    assert_eq!(snap.engine_refined, 0, "unrefined traffic: {}", snap.report());
    assert!(snap.engine_flushes >= 3, "three edges -> at least three buckets: {}", snap.report());
    // every operand byte reached the engine by borrow (zero per-entry
    // clones on the bucketed lane): 24 requests x 2 operands x n^2 f32s
    let want_bytes: u64 = (0..24usize).map(|i| [24u64, 48, 33][i % 3].pow(2) * 2 * 4).sum();
    assert_eq!(snap.engine_view_bytes, want_bytes, "{}", snap.report());
    assert_eq!(snap.responses, 24);
    c.shutdown();
}

#[test]
fn refined_square_requests_ride_engine_lane_with_zero_fallbacks() {
    // the acceptance check for this PR's tentpole: a refined square
    // workload over an injected empty manifest keeps the CPU-fallback
    // counter at exactly zero — refined requests bucket onto mode-keyed
    // cached plans and come back bitwise equal to the refine_gemm chains
    let c = engine_only_coordinator();
    let mut rng = Rng::new(14);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..18u64 {
        let n = [24usize, 33, 24][(i % 3) as usize];
        let mode = [RefineMode::RefineA, RefineMode::RefineAB][(i % 2) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push((mode, refine_gemm(&a, &b, mode)));
        rxs.push(c.submit(GemmRequest::new(0, a, b).with_mode(mode)));
    }
    for (rx, (mode, want)) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, mode);
        // the engine lane is the host engine: bitwise equal to the chain
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "refined square must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 18, "{}", snap.report());
    assert_eq!(snap.engine_refined, 18, "{}", snap.report());
    assert!(snap.engine_view_bytes > 0, "refined buckets gather by view too: {}", snap.report());
    assert_eq!(snap.responses, 18);
    c.shutdown();
}

#[test]
fn format_mode_squares_ride_engine_lane_with_zero_fallbacks() {
    // the acceptance check for this PR's tentpole: square requests at
    // every new format mode, submitted to an artifact-free coordinator,
    // are served by the batched engine lane (CPU-fallback counter stays
    // 0) and come back bitwise equal to each format's scalar oracle
    let c = engine_only_coordinator();
    let scale = Scale::new(0.25);
    let modes: [PrecisionMode; 4] = [
        PrecisionMode::Bf16,
        PrecisionMode::Tf32,
        PrecisionMode::Fp8E4M3,
        PrecisionMode::Int8(scale),
    ];
    let oracle = |mode: PrecisionMode, a: &Matrix, b: &Matrix| match mode {
        PrecisionMode::Bf16 => bf16_gemm_scalar(a, b, None, 1.0, 0.0),
        PrecisionMode::Tf32 => tf32_gemm_scalar(a, b, None, 1.0, 0.0),
        PrecisionMode::Fp8E4M3 => fp8_gemm_scalar(a, b, None, 1.0, 0.0),
        PrecisionMode::Int8(s) => int8_gemm_scalar(a, b, None, 1.0, 0.0, s.get()),
        PrecisionMode::Refined(_) | PrecisionMode::Sparse24 => unreachable!("format-only sweep"),
    };
    let mut rng = Rng::new(16);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..24u64 {
        let n = [24usize, 33][(i % 2) as usize];
        let mode = modes[(i % 4) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push((mode, oracle(mode, &a, &b)));
        rxs.push(c.submit(GemmRequest::new(0, a, b).with_mode(mode)));
    }
    for (rx, (mode, want)) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine, "mode {mode}");
        assert_eq!(resp.mode, mode);
        // the engine lane quantizes at pack time: bitwise oracle match
        assert_eq!(resp.c, want, "mode {mode}");
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "format squares must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 24, "{}", snap.report());
    assert_eq!(snap.engine_refined, 0, "format buckets are not refined: {}", snap.report());
    assert!(snap.engine_flushes >= 8, "8 (edge, mode) keys: {}", snap.report());
    assert_eq!(snap.responses, 24);
    c.shutdown();
}

#[test]
fn sparse_mode_squares_ride_engine_lane_with_zero_fallbacks() {
    // the sparse lane's acceptance check: a burst of sparse24 square
    // requests over an injected empty manifest buckets on the batched
    // engine lane — CPU-fallback counter pinned at exactly zero — and
    // every reply is bitwise equal to the serial sparse oracle
    let c = engine_only_coordinator();
    let mut rng = Rng::new(17);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..16u64 {
        let n = [24usize, 33][(i % 2) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(sparse24_gemm_scalar(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b).with_mode(PrecisionMode::Sparse24)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, PrecisionMode::Sparse24);
        // the engine lane prunes at pack time: bitwise oracle match
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "sparse squares must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 16, "{}", snap.report());
    assert_eq!(snap.engine_refined, 0, "sparse buckets are not refined: {}", snap.report());
    assert!(snap.engine_flushes >= 2, "two (edge, sparse24) keys: {}", snap.report());
    assert_eq!(snap.responses, 16);
    c.shutdown();
}

#[test]
fn sparse_and_dense_same_edge_bucket_separately() {
    // mode-aware bucketing at service level: one tight same-edge burst,
    // half dense / half sparse24 — every response must come back at its
    // own mode (same-bucket mixing would prune the dense half), each
    // bitwise equal to its own oracle
    let c = engine_only_coordinator();
    let mut rng = Rng::new(18);
    let inputs: Vec<(Matrix, Matrix, bool)> = (0..16)
        .map(|i| {
            (
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                i % 2 == 1,
            )
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b, sparse) in &inputs {
        let mut req = GemmRequest::new(0, a.clone(), b.clone());
        if *sparse {
            req = req.with_mode(PrecisionMode::Sparse24);
        }
        rxs.push(c.submit(req));
    }
    for (rx, (a, b, sparse)) in rxs.into_iter().zip(&inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        let want = if *sparse {
            assert_eq!(resp.mode, PrecisionMode::Sparse24);
            sparse24_gemm_scalar(a, b, None, 1.0, 0.0)
        } else {
            assert_eq!(resp.mode, RefineMode::None);
            mixed_gemm(a, b, None, 1.0, 0.0)
        };
        assert_eq!(resp.c, want, "sparse={sparse}");
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "{}", snap.report());
    assert_eq!(snap.engine_batched, 16, "{}", snap.report());
    assert!(snap.engine_flushes >= 2, "modes must never share a bucket: {}", snap.report());
    c.shutdown();
}

#[test]
fn mixed_and_refined_same_edge_bucket_separately() {
    // mode-aware bucketing at service level: one tight same-edge burst,
    // half unrefined / half RefineAB — every response must come back at
    // its own mode (same-bucket mixing would corrupt one half), and the
    // refined counter must see exactly the refined half
    let c = engine_only_coordinator();
    let mut rng = Rng::new(15);
    let inputs: Vec<(Matrix, Matrix, RefineMode)> = (0..16)
        .map(|i| {
            let mode = if i % 2 == 0 { RefineMode::None } else { RefineMode::RefineAB };
            (
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                mode,
            )
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b, mode) in &inputs {
        let req = GemmRequest::new(0, a.clone(), b.clone()).with_mode(*mode);
        rxs.push(c.submit(req));
    }
    for (rx, (a, b, mode)) in rxs.into_iter().zip(&inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, *mode);
        let want = match mode {
            RefineMode::None => mixed_gemm(a, b, None, 1.0, 0.0),
            refined => refine_gemm(a, b, *refined),
        };
        assert_eq!(resp.c, want, "mode {mode:?}");
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 0, "{}", snap.report());
    assert_eq!(snap.engine_batched, 16, "{}", snap.report());
    assert_eq!(snap.engine_refined, 8, "{}", snap.report());
    assert!(snap.engine_flushes >= 2, "modes must never share a bucket: {}", snap.report());
    c.shutdown();
}

#[test]
fn engine_lane_buckets_requests_instead_of_serving_singly() {
    // a same-edge burst must drain as few buckets, not 16 one-request
    // flushes — the batching half of the engine-lane claim
    let c = engine_only_coordinator();
    let mut rng = Rng::new(12);
    // generate inputs first so the submit burst is as tight as possible
    let inputs: Vec<(Matrix, Matrix)> = (0..16)
        .map(|_| {
            (
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
            )
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b) in inputs {
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.engine_batched, 16);
    assert!(
        snap.engine_flushes < 16,
        "burst must be bucketed, not served one-by-one ({})",
        snap.report()
    );
    c.shutdown();
}

#[test]
fn non_square_requests_still_fall_back_without_artifacts() {
    let c = engine_only_coordinator();
    let mut rng = Rng::new(13);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    let resp = c.gemm(a, b).unwrap();
    assert_eq!(resp.served_by, ServedBy::CpuFallback);
    assert_eq!(resp.c, want);
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 1);
    assert_eq!(snap.engine_batched, 0);
    assert_eq!(snap.engine_view_bytes, 0);
    c.shutdown();
}

// ---------------------------------------------------------------------------
// Reply-delivery totality sweep: every submitted request gets exactly one
// reply — success or typed error, never a hung channel — across
// shed-under-burst, shutdown-while-pending, and worker panic injection,
// on both the engine-batcher and artifact lanes.  No test below relies
// on sleeps for correctness: deadlines are explicit `Instant`s, and the
// shed/shutdown tests use batcher timers too large to ever fire.
// ---------------------------------------------------------------------------

/// Submit `count` square `n`-edge requests as one tight burst against a
/// coordinator capped at `cap`, then collect every reply after shutdown.
/// Returns (ok, shed, shutdown) counts; panics on any other reply kind
/// or a missing one.
fn burst_and_collect(c: Coordinator, cap: usize, count: usize, n: usize) -> (usize, usize, usize) {
    let mut rng = Rng::new(21);
    let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
    let rxs: Vec<_> =
        (0..count).map(|_| c.submit(GemmRequest::new(0, a.clone(), b.clone()))).collect();
    let high_water = c.metrics_snapshot().max_queue_depth;
    c.shutdown();
    let (mut ok, mut shed, mut shutdown) = (0, 0, 0);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered") {
            Ok(_) => ok += 1,
            Err(CoordinatorError::Shed { queue_depth }) => {
                assert!(queue_depth >= cap, "shed at depth {queue_depth} below cap {cap}");
                shed += 1;
            }
            Err(CoordinatorError::ShuttingDown) => shutdown += 1,
            Err(e) => panic!("unexpected reply {e}"),
        }
    }
    assert_eq!(ok + shed + shutdown, count, "exactly one reply per request");
    assert!(high_water <= cap as u64, "queue bounded by cap: max depth {high_water}");
    (ok, shed, shutdown)
}

#[test]
fn shed_under_burst_bounds_queue_engine_lane() {
    // 64 requests against a cap of 8 with batchers that can never flush:
    // exactly 8 admitted (answered ShuttingDown at shutdown), 56 shed
    // with the typed admission error — and the queue never exceeds 8
    let c = engine_only_coordinator_cfg(never_flush_cfg(8));
    let (ok, shed, shutdown) = burst_and_collect(c, 8, 64, 16);
    assert_eq!(shed, 56, "ok={ok} shed={shed} shutdown={shutdown}");
    assert_eq!(ok + shutdown, 8);
}

#[test]
fn shed_under_burst_bounds_queue_artifact_lane() {
    // the same contract on the artifact lane.  The service clamps
    // max_batch to the real artifact's batch capacity, so capacity
    // flushes may drain admitted work mid-burst — the exact shed count
    // is not deterministic here, but the bound, the totality, and the
    // presence of typed sheds are.
    let Some(c) = coordinator_cfg(never_flush_cfg(8)) else { return };
    let (ok, shed, shutdown) = burst_and_collect(c, 8, 64, 16);
    assert!(shed >= 1, "ok={ok} shed={shed} shutdown={shutdown}");
}

#[test]
fn shutdown_while_pending_delivers_shutting_down() {
    // queued-but-unflushed work is answered ShuttingDown — channels are
    // never dropped unanswered
    let c = engine_only_coordinator_cfg(never_flush_cfg(4096));
    let mut rng = Rng::new(22);
    let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let rxs: Vec<_> =
        (0..5).map(|_| c.submit(GemmRequest::new(0, a.clone(), b.clone()))).collect();
    c.shutdown();
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered");
        assert_eq!(reply.unwrap_err(), CoordinatorError::ShuttingDown);
    }
}

#[test]
fn worker_panic_becomes_typed_internal_engine_lane() {
    // a poisoned request panics its engine-lane worker: the panic comes
    // back as a typed Internal reply, the cohort in *other* buckets is
    // untouched, and the service keeps serving afterwards
    let c = engine_only_coordinator();
    let mut rng = Rng::new(23);
    let pa = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let pb = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let ha = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let hb = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let rx_poison = c.submit(GemmRequest::new(0, pa, pb).with_poison());
    let rx_healthy = c.submit(GemmRequest::new(0, ha.clone(), hb.clone()));
    let poisoned = rx_poison.recv_timeout(Duration::from_secs(30)).unwrap();
    match poisoned {
        Err(CoordinatorError::Internal(msg)) => assert!(msg.contains("poison"), "{msg}"),
        other => panic!("expected Internal, got {other:?}"),
    }
    let healthy = rx_healthy.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(healthy.c, mixed_gemm(&ha, &hb, None, 1.0, 0.0));
    // the dispatcher survived the worker panic: the service still serves
    let again = c.gemm(ha.clone(), hb.clone()).unwrap();
    assert_eq!(again.c, mixed_gemm(&ha, &hb, None, 1.0, 0.0));
    let snap = c.metrics_snapshot();
    assert_eq!(snap.errors, 1, "{}", snap.report());
    c.shutdown();
}

#[test]
fn worker_panic_becomes_typed_internal_fallback_lane() {
    // same isolation on the CPU-fallback lane (non-square request)
    let c = engine_only_coordinator();
    let mut rng = Rng::new(24);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let reply = c.gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_poison());
    match reply {
        Err(CoordinatorError::Internal(msg)) => assert!(msg.contains("poison"), "{msg}"),
        other => panic!("expected Internal, got {other:?}"),
    }
    // service alive: the same shape unpoisoned is served
    assert!(c.gemm(a, b).is_ok());
    c.shutdown();
}

#[test]
fn worker_panic_fans_out_typed_internal_artifact_lane() {
    // a poisoned entry riding an artifact-lane batch panics the flush
    // worker: every request on that batch gets a typed Internal reply
    // (never a hung channel), and the service keeps serving
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(25);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let mut rxs = Vec::new();
    for i in 0..24 {
        let req = GemmRequest::new(0, a.clone(), b.clone());
        rxs.push(c.submit(if i == 7 { req.with_poison() } else { req }));
    }
    let (mut ok, mut internal) = (0, 0);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered") {
            Ok(_) => ok += 1,
            Err(CoordinatorError::Internal(_)) => internal += 1,
            Err(e) => panic!("unexpected reply {e}"),
        }
    }
    assert_eq!(ok + internal, 24, "exactly one reply per request");
    assert!(internal >= 1, "the poisoned batch must fail typed (ok={ok})");
    assert!(c.gemm(a, b).is_ok(), "service must survive the poisoned batch");
    c.shutdown();
}

#[test]
fn expired_deadline_is_shed_at_dispatch() {
    // a request arriving with its deadline already behind `now` is shed
    // with the typed error instead of executed — deadline injected as an
    // explicit past Instant, no sleeping anywhere
    let c = engine_only_coordinator_cfg(never_flush_cfg(4096));
    let mut rng = Rng::new(26);
    let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let expired = Instant::now() - Duration::from_secs(1);
    let reply = c.gemm_with(GemmRequest::new(0, a, b).with_deadline(expired));
    assert_eq!(reply.unwrap_err(), CoordinatorError::DeadlineExceeded);
    let snap = c.metrics_snapshot();
    assert_eq!(snap.deadline_exceeded, 1, "{}", snap.report());
    assert_eq!(snap.errors, 0, "deadline sheds are not service errors: {}", snap.report());
    c.shutdown();
}

#[test]
fn near_deadline_triggers_early_flush_engine_lane() {
    // age timer far away (100000s), deadline 60s out, slack 120s: the
    // only trigger that can serve this request is the deadline-urgency
    // flush — and it must fire immediately, not in 100000s
    let mut cfg = never_flush_cfg(4096);
    cfg.batcher.deadline_slack = Duration::from_secs(120);
    let c = engine_only_coordinator_cfg(cfg);
    let mut rng = Rng::new(27);
    let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let deadline = Instant::now() + Duration::from_secs(60);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_deadline(deadline))
        .unwrap();
    assert_eq!(resp.served_by, ServedBy::BatchedEngine);
    assert_eq!(resp.c, mixed_gemm(&a, &b, None, 1.0, 0.0));
    let snap = c.metrics_snapshot();
    assert!(snap.flush_early_engine >= 1, "{}", snap.report());
    c.shutdown();
}

#[test]
fn near_deadline_triggers_early_flush_artifact_lane() {
    // the artifact-lane twin of the early-flush test
    let mut cfg = never_flush_cfg(4096);
    cfg.batcher.deadline_slack = Duration::from_secs(120);
    let Some(c) = coordinator_cfg(cfg) else { return };
    let mut rng = Rng::new(28);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let deadline = Instant::now() + Duration::from_secs(60);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_deadline(deadline))
        .unwrap();
    assert_eq!(resp.served_by, ServedBy::BatchedTensorCore);
    let snap = c.metrics_snapshot();
    assert!(snap.flush_early_artifact >= 1, "{}", snap.report());
    c.shutdown();
}

#[test]
fn gemm_deadline_maps_timeout_to_typed_error() {
    // batchers can never flush, so the reply cannot arrive: the caller's
    // timeout must come back as the typed DeadlineExceeded (the request
    // itself is later answered ShuttingDown on drop — still one reply)
    let c = engine_only_coordinator_cfg(never_flush_cfg(4096));
    let mut rng = Rng::new(29);
    let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let reply = c.gemm_deadline(GemmRequest::new(0, a, b), Duration::from_millis(100));
    assert_eq!(reply.unwrap_err(), CoordinatorError::DeadlineExceeded);
    c.shutdown();
}

// ---------------------------------------------------------------------------
// Sharded-intake invariants: the global admission bound, reply totality
// and fault isolation must hold with shards > 1 exactly as they did for
// the single-dispatcher service, same-key requests must co-bucket on one
// shard, and shards = 1 must be behaviorally identical to the
// pre-sharding coordinator.
// ---------------------------------------------------------------------------

#[test]
fn coordinator_is_sync_for_concurrent_submitters() {
    // the replay harness drives one &Coordinator from many scoped
    // threads — compile-time guarantee that stays possible
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Coordinator>();
}

#[test]
fn default_shards_resolve_to_at_least_one() {
    let c = engine_only_coordinator();
    assert!(c.shards() >= 1, "shards: 0 must resolve to one shard per core");
    c.shutdown();
}

#[test]
fn concurrent_multi_shard_burst_bounds_global_queue_exactly() {
    // 4 submitter threads x 16 requests over 8 distinct edges against a
    // global cap of 8, batchers that can never flush: admission is one
    // shared counter, so exactly 8 requests are admitted (answered
    // ShuttingDown) and exactly 56 shed — no matter how threads and
    // shards interleave — and no shard ever observes a depth above 8
    let c = engine_only_coordinator_cfg(CoordinatorConfig { shards: 4, ..never_flush_cfg(8) });
    assert_eq!(c.shards(), 4);
    let mut rng = Rng::new(31);
    let edges = [8usize, 16, 24, 33, 40, 48, 56, 64];
    let operands: Vec<(Matrix, Matrix)> = edges
        .iter()
        .map(|&n| {
            (uniform_matrix(&mut rng, n, n, -1.0, 1.0), uniform_matrix(&mut rng, n, n, -1.0, 1.0))
        })
        .collect();
    let mut rxs = Vec::new();
    std::thread::scope(|s| {
        let (c, operands) = (&c, &operands);
        let handles: Vec<_> = (0..4)
            .map(|w| {
                s.spawn(move || {
                    (0..16)
                        .map(|i| {
                            let (a, b) = operands[(w * 16 + i) % operands.len()].clone();
                            c.submit(GemmRequest::new(0, a, b))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            rxs.extend(h.join().expect("submitter thread panicked"));
        }
    });
    // snapshots before shutdown consumes the coordinator: all submits
    // (and their shed accounting) completed when the scope joined
    let merged = c.metrics_snapshot();
    let per_shard = c.shard_snapshots();
    c.shutdown();
    let (mut ok, mut shed, mut shutdown) = (0, 0, 0);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered") {
            Ok(_) => ok += 1,
            Err(CoordinatorError::Shed { queue_depth }) => {
                assert!(queue_depth >= 8, "shed at depth {queue_depth} below the global cap");
                shed += 1;
            }
            Err(CoordinatorError::ShuttingDown) => shutdown += 1,
            Err(e) => panic!("unexpected reply {e}"),
        }
    }
    assert_eq!(shed, 56, "ok={ok} shutdown={shutdown}");
    assert_eq!(ok + shutdown, 8);
    assert!(merged.max_queue_depth <= 8, "global bound violated: {}", merged.report());
    assert!(per_shard.iter().all(|s| s.max_queue_depth <= 8), "a shard saw depth above cap");
    // exact aggregation: the merged view is the sum of the rows
    assert_eq!(per_shard.iter().map(|s| s.requests).sum::<u64>(), 64);
    assert_eq!(per_shard.iter().map(|s| s.shed).sum::<u64>(), 56);
    assert_eq!(merged.requests, 64, "{}", merged.report());
    assert_eq!(merged.shed, 56, "{}", merged.report());
}

#[test]
fn same_key_requests_co_bucket_on_one_shard() {
    // 16 requests of one (edge, mode) key through a 4-shard service:
    // the stable routing hash must land every one on the same shard —
    // and, on that shard, they must batch instead of serving singly
    // (the bucket-density property sharding exists to preserve)
    let c = engine_only_coordinator_cfg(CoordinatorConfig {
        shards: 4,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
        ..Default::default()
    });
    let mut rng = Rng::new(32);
    let inputs: Vec<(Matrix, Matrix)> = (0..16)
        .map(|_| {
            let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
            let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
            (a, b)
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b) in inputs {
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
    }
    let per_shard = c.shard_snapshots();
    let busy: Vec<usize> =
        (0..per_shard.len()).filter(|&i| per_shard[i].requests > 0).collect();
    assert_eq!(busy.len(), 1, "one bucket key spread over shards {busy:?}");
    let s = &per_shard[busy[0]];
    assert_eq!(s.requests, 16);
    assert_eq!(s.engine_batched, 16, "{}", s.report());
    assert!(s.engine_flushes < 16, "co-bucketed burst must batch: {}", s.report());
    c.shutdown();
}

#[test]
fn sharded_shutdown_while_pending_answers_every_shard() {
    // pending work spread over several shards' batchers: shutdown must
    // answer ShuttingDown on every shard — no channel on any shard is
    // dropped unanswered
    let c = engine_only_coordinator_cfg(CoordinatorConfig { shards: 4, ..never_flush_cfg(4096) });
    let mut rng = Rng::new(33);
    let mut rxs = Vec::new();
    for &n in &[16usize, 24, 33, 48] {
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        for _ in 0..3 {
            rxs.push(c.submit(GemmRequest::new(0, a.clone(), b.clone())));
        }
    }
    c.shutdown();
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered");
        assert_eq!(reply.unwrap_err(), CoordinatorError::ShuttingDown);
    }
}

#[test]
fn sharded_worker_panic_stays_isolated() {
    // a poisoned bucket on one shard panics its worker: the poison
    // comes back typed, traffic on other keys (other shards) is
    // untouched, and the whole service keeps serving afterwards
    let c = engine_only_coordinator_cfg(CoordinatorConfig { shards: 4, ..Default::default() });
    let mut rng = Rng::new(34);
    let pa = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let pb = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let ha = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let hb = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let rx_poison = c.submit(GemmRequest::new(0, pa, pb).with_poison());
    let rx_healthy = c.submit(GemmRequest::new(0, ha.clone(), hb.clone()));
    match rx_poison.recv_timeout(Duration::from_secs(30)).unwrap() {
        Err(CoordinatorError::Internal(msg)) => assert!(msg.contains("poison"), "{msg}"),
        other => panic!("expected Internal, got {other:?}"),
    }
    let healthy = rx_healthy.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(healthy.c, mixed_gemm(&ha, &hb, None, 1.0, 0.0));
    let again = c.gemm(ha.clone(), hb.clone()).unwrap();
    assert_eq!(again.c, mixed_gemm(&ha, &hb, None, 1.0, 0.0));
    let snap = c.metrics_snapshot();
    assert_eq!(snap.errors, 1, "{}", snap.report());
    c.shutdown();
}

#[test]
fn single_shard_matches_single_dispatcher_behavior() {
    // shards = 1 is the PR 6 coordinator: the same never-flush burst
    // produces the same exact counts (8 admitted, 56 shed), and the
    // merged metrics view IS the one shard's view
    let c = engine_only_coordinator_cfg(CoordinatorConfig { shards: 1, ..never_flush_cfg(8) });
    assert_eq!(c.shards(), 1);
    let mut rng = Rng::new(35);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let rxs: Vec<_> =
        (0..64).map(|_| c.submit(GemmRequest::new(0, a.clone(), b.clone()))).collect();
    let merged = c.metrics_snapshot();
    let per_shard = c.shard_snapshots();
    assert_eq!(per_shard.len(), 1);
    assert_eq!(merged.requests, per_shard[0].requests);
    assert_eq!(merged.shed, per_shard[0].shed);
    assert_eq!(merged.max_queue_depth, per_shard[0].max_queue_depth);
    c.shutdown();
    let (mut ok, mut shed, mut shutdown) = (0, 0, 0);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("reply must be delivered") {
            Ok(_) => ok += 1,
            Err(CoordinatorError::Shed { .. }) => shed += 1,
            Err(CoordinatorError::ShuttingDown) => shutdown += 1,
            Err(e) => panic!("unexpected reply {e}"),
        }
    }
    assert_eq!(shed, 56, "ok={ok} shutdown={shutdown}");
    assert_eq!(ok + shutdown, 8);
    assert!(merged.max_queue_depth <= 8);
}

#[test]
fn fallback_threads_bounded_with_high_water_metric() {
    // cap the one-shot lanes at a single worker: a burst of 6 odd-shaped
    // requests is still served completely (jobs past the cap queue in
    // the gate and drain in turn), and the high-water metric shows the
    // bound was respected exactly
    let c = engine_only_coordinator_cfg(CoordinatorConfig {
        max_fallback_threads: 1,
        ..Default::default()
    });
    let mut rng = Rng::new(36);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    let rxs: Vec<_> =
        (0..6).map(|_| c.submit(GemmRequest::new(0, a.clone(), b.clone()))).collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::CpuFallback);
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics_snapshot();
    assert_eq!(snap.fallback, 6, "{}", snap.report());
    assert_eq!(snap.fallback_inflight, 1, "cap 1 -> high-water exactly 1: {}", snap.report());
    c.shutdown();
}

#[test]
fn pm16_inputs_budget_escalates_precision() {
    // the §VII-B scenario as service behaviour: same budget, ±16 inputs
    // -> the policy must refine
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(9);
    let n = 512;
    let a = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let b = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let resp = c
        .gemm_with(
            GemmRequest::new(0, a.clone(), b.clone())
                .with_error_budget(0.05)
                .with_scale(16.0),
        )
        .unwrap();
    assert_ne!(resp.mode, RefineMode::None, "±16 inputs must trigger refinement");
    c.shutdown();
}
