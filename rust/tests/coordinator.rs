//! Integration tests for the GEMM coordinator over real PJRT artifacts
//! (requires `make artifacts`).

use std::time::Duration;

use tensoremu::coordinator::request::ServedBy;
use tensoremu::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, GemmRequest};
use tensoremu::gemm::{mixed_gemm, Matrix};
use tensoremu::precision::{refine_gemm, RefineMode};
use tensoremu::runtime::is_artifacts_missing;
use tensoremu::workload::{uniform_matrix, Rng};

/// Skips (returns None) when the PJRT artifacts are not built — the
/// coordinator cannot start without a manifest.  Only that case skips;
/// any other startup failure panics so regressions stay visible.
fn coordinator() -> Option<Coordinator> {
    match Coordinator::start(CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(3) },
        ..Default::default()
    }) {
        Ok(c) => Some(c),
        Err(e) if is_artifacts_missing(&e) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
        Err(e) => panic!("coordinator startup failed (not a missing build): {e:#}"),
    }
}

#[test]
fn serves_a_large_gemm_on_tensor_core_path() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(1);
    let a = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::TensorCore);
    assert_eq!(resp.mode, RefineMode::None);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn batches_tile_requests_together() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(2);
    // submit a burst of 16x16 requests, then collect
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..40 {
        let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(0, a.clone(), b.clone())));
        inputs.push((a, b));
    }
    for (rx, (a, b)) in rxs.into_iter().zip(inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedTensorCore);
        let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.responses, 40);
    assert_eq!(snap.batched, 40);
    assert!(snap.flushes >= 1, "expected at least one flush");
    assert!(
        snap.flushes < 40,
        "requests must be batched, not served one-by-one (flushes = {})",
        snap.flushes
    );
    c.shutdown();
}

#[test]
fn error_budget_selects_refined_artifact() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(3);
    let a = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_error_budget(1e-7))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineAB);
    let want = refine_gemm(&a, &b, RefineMode::RefineAB);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn explicit_mode_respected() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(4);
    let a = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_mode(RefineMode::RefineA))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineA);
    let want = refine_gemm(&a, &b, RefineMode::RefineA);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn odd_shapes_served_by_cpu_fallback() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(5);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::CpuFallback);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-5);
    assert_eq!(c.metrics().snapshot().fallback, 1);
    c.shutdown();
}

#[test]
fn mixed_traffic_all_served_correctly() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(6);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..30 {
        let n = match i % 3 {
            0 => 16,
            1 => 64,
            _ => 128,
        };
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(mixed_gemm(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.responses, 30);
    assert!(snap.batched == 10 && snap.direct == 20, "{}", snap.report());
    c.shutdown();
}

#[test]
fn response_ids_match_requests() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(7);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let rx = c.submit(GemmRequest::new(4242, a, b));
    let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(resp.id, 4242);
    c.shutdown();
}

#[test]
fn latency_accounting_present() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(8);
    let a = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let resp = c.gemm(a, b).unwrap();
    assert!(resp.exec > Duration::ZERO);
    let snap = c.metrics().snapshot();
    assert!(snap.p50 > Duration::ZERO);
    c.shutdown();
}

#[test]
fn pm16_inputs_budget_escalates_precision() {
    // the §VII-B scenario as service behaviour: same budget, ±16 inputs
    // -> the policy must refine
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(9);
    let n = 512;
    let a = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let b = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let resp = c
        .gemm_with(
            GemmRequest::new(0, a.clone(), b.clone())
                .with_error_budget(0.05)
                .with_scale(16.0),
        )
        .unwrap();
    assert_ne!(resp.mode, RefineMode::None, "±16 inputs must trigger refinement");
    c.shutdown();
}
