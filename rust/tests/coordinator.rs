//! Integration tests for the GEMM coordinator.  Most require real PJRT
//! artifacts (`make artifacts`) and skip without them; the engine-lane
//! tests at the bottom inject an *empty* manifest instead — no artifact
//! can serve anything there, which is exactly the regime the cached-plan
//! bucketed engine lane exists for — so they run everywhere.

use std::time::Duration;

use tensoremu::coordinator::request::ServedBy;
use tensoremu::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, GemmRequest};
use tensoremu::gemm::{mixed_gemm, Matrix};
use tensoremu::precision::{refine_gemm, RefineMode};
use tensoremu::runtime::{is_artifacts_missing, ExecutorServer, Manifest};
use tensoremu::workload::{uniform_matrix, Rng};

/// Skips (returns None) when the PJRT artifacts are not built — the
/// coordinator cannot start without a manifest.  Only that case skips;
/// any other startup failure panics so regressions stay visible.
fn coordinator() -> Option<Coordinator> {
    match Coordinator::start(CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(3) },
        ..Default::default()
    }) {
        Ok(c) => Some(c),
        Err(e) if is_artifacts_missing(&e) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
        Err(e) => panic!("coordinator startup failed (not a missing build): {e:#}"),
    }
}

#[test]
fn serves_a_large_gemm_on_tensor_core_path() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(1);
    let a = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::TensorCore);
    assert_eq!(resp.mode, RefineMode::None);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn batches_tile_requests_together() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(2);
    // submit a burst of 16x16 requests, then collect
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..40 {
        let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(0, a.clone(), b.clone())));
        inputs.push((a, b));
    }
    for (rx, (a, b)) in rxs.into_iter().zip(inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedTensorCore);
        let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.responses, 40);
    assert_eq!(snap.batched, 40);
    assert!(snap.flushes >= 1, "expected at least one flush");
    assert!(
        snap.flushes < 40,
        "requests must be batched, not served one-by-one (flushes = {})",
        snap.flushes
    );
    c.shutdown();
}

#[test]
fn error_budget_selects_refined_artifact() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(3);
    let a = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_error_budget(1e-7))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineAB);
    let want = refine_gemm(&a, &b, RefineMode::RefineAB);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn explicit_mode_respected() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(4);
    let a = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 128, 128, -1.0, 1.0);
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_mode(RefineMode::RefineA))
        .unwrap();
    assert_eq!(resp.mode, RefineMode::RefineA);
    let want = refine_gemm(&a, &b, RefineMode::RefineA);
    assert!(resp.c.max_norm_diff(&want) < 1e-4);
    c.shutdown();
}

#[test]
fn odd_shapes_served_by_cpu_fallback() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(5);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.served_by, ServedBy::CpuFallback);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    assert!(resp.c.max_norm_diff(&want) < 1e-5);
    assert_eq!(c.metrics().snapshot().fallback, 1);
    c.shutdown();
}

#[test]
fn mixed_traffic_all_served_correctly() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(6);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..30 {
        let n = match i % 3 {
            0 => 16,
            1 => 64,
            _ => 128,
        };
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(mixed_gemm(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        assert!(resp.c.max_norm_diff(&want) < 1e-4);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.responses, 30);
    assert!(snap.batched == 10 && snap.direct == 20, "{}", snap.report());
    c.shutdown();
}

#[test]
fn response_ids_match_requests() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(7);
    let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let rx = c.submit(GemmRequest::new(4242, a, b));
    let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(resp.id, 4242);
    c.shutdown();
}

#[test]
fn latency_accounting_present() {
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(8);
    let a = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 64, 64, -1.0, 1.0);
    let resp = c.gemm(a, b).unwrap();
    assert!(resp.exec > Duration::ZERO);
    let snap = c.metrics().snapshot();
    assert!(snap.p50 > Duration::ZERO);
    c.shutdown();
}

/// A coordinator over an *empty* manifest: no batched artifact, no
/// direct artifacts — every square request must ride the bucketed
/// engine lane, and only non-square requests may fall back.  Needs no
/// built artifacts, so it runs on every machine.
fn engine_only_coordinator() -> Coordinator {
    let manifest = Manifest { dir: std::path::PathBuf::from("unbuilt"), artifacts: Vec::new() };
    let executor = ExecutorServer::start(manifest).expect("executor over empty manifest");
    Coordinator::start_with(
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) },
            ..Default::default()
        },
        executor,
    )
    .expect("coordinator over empty manifest")
}

#[test]
fn square_non_tile_requests_ride_engine_lane_with_zero_fallbacks() {
    // the acceptance check for the PR 2 open item: a square non-tile
    // workload keeps the CPU-fallback counter at exactly zero and is
    // served bitwise-correctly through cached per-edge plans
    let c = engine_only_coordinator();
    let mut rng = Rng::new(11);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..24u64 {
        let n = [24usize, 48, 33][(i % 3) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push(mixed_gemm(&a, &b, None, 1.0, 0.0));
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, RefineMode::None);
        // the engine lane is the host engine: bitwise equal to the oracle
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.fallback, 0, "square requests must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 24, "{}", snap.report());
    assert_eq!(snap.engine_refined, 0, "unrefined traffic: {}", snap.report());
    assert!(snap.engine_flushes >= 3, "three edges -> at least three buckets: {}", snap.report());
    // every operand byte reached the engine by borrow (zero per-entry
    // clones on the bucketed lane): 24 requests x 2 operands x n^2 f32s
    let want_bytes: u64 = (0..24usize).map(|i| [24u64, 48, 33][i % 3].pow(2) * 2 * 4).sum();
    assert_eq!(snap.engine_view_bytes, want_bytes, "{}", snap.report());
    assert_eq!(snap.responses, 24);
    c.shutdown();
}

#[test]
fn refined_square_requests_ride_engine_lane_with_zero_fallbacks() {
    // the acceptance check for this PR's tentpole: a refined square
    // workload over an injected empty manifest keeps the CPU-fallback
    // counter at exactly zero — refined requests bucket onto mode-keyed
    // cached plans and come back bitwise equal to the refine_gemm chains
    let c = engine_only_coordinator();
    let mut rng = Rng::new(14);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..18u64 {
        let n = [24usize, 33, 24][(i % 3) as usize];
        let mode = [RefineMode::RefineA, RefineMode::RefineAB][(i % 2) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        wants.push((mode, refine_gemm(&a, &b, mode)));
        rxs.push(c.submit(GemmRequest::new(0, a, b).with_mode(mode)));
    }
    for (rx, (mode, want)) in rxs.into_iter().zip(wants) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, mode);
        // the engine lane is the host engine: bitwise equal to the chain
        assert_eq!(resp.c, want);
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.fallback, 0, "refined square must never fall back: {}", snap.report());
    assert_eq!(snap.engine_batched, 18, "{}", snap.report());
    assert_eq!(snap.engine_refined, 18, "{}", snap.report());
    assert!(snap.engine_view_bytes > 0, "refined buckets gather by view too: {}", snap.report());
    assert_eq!(snap.responses, 18);
    c.shutdown();
}

#[test]
fn mixed_and_refined_same_edge_bucket_separately() {
    // mode-aware bucketing at service level: one tight same-edge burst,
    // half unrefined / half RefineAB — every response must come back at
    // its own mode (same-bucket mixing would corrupt one half), and the
    // refined counter must see exactly the refined half
    let c = engine_only_coordinator();
    let mut rng = Rng::new(15);
    let inputs: Vec<(Matrix, Matrix, RefineMode)> = (0..16)
        .map(|i| {
            let mode = if i % 2 == 0 { RefineMode::None } else { RefineMode::RefineAB };
            (
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                mode,
            )
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b, mode) in &inputs {
        let req = GemmRequest::new(0, a.clone(), b.clone()).with_mode(*mode);
        rxs.push(c.submit(req));
    }
    for (rx, (a, b, mode)) in rxs.into_iter().zip(&inputs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.served_by, ServedBy::BatchedEngine);
        assert_eq!(resp.mode, *mode);
        let want = match mode {
            RefineMode::None => mixed_gemm(a, b, None, 1.0, 0.0),
            refined => refine_gemm(a, b, *refined),
        };
        assert_eq!(resp.c, want, "mode {mode:?}");
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.fallback, 0, "{}", snap.report());
    assert_eq!(snap.engine_batched, 16, "{}", snap.report());
    assert_eq!(snap.engine_refined, 8, "{}", snap.report());
    assert!(snap.engine_flushes >= 2, "modes must never share a bucket: {}", snap.report());
    c.shutdown();
}

#[test]
fn engine_lane_buckets_requests_instead_of_serving_singly() {
    // a same-edge burst must drain as few buckets, not 16 one-request
    // flushes — the batching half of the engine-lane claim
    let c = engine_only_coordinator();
    let mut rng = Rng::new(12);
    // generate inputs first so the submit burst is as tight as possible
    let inputs: Vec<(Matrix, Matrix)> = (0..16)
        .map(|_| {
            (
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
                uniform_matrix(&mut rng, 24, 24, -1.0, 1.0),
            )
        })
        .collect();
    let mut rxs = Vec::new();
    for (a, b) in inputs {
        rxs.push(c.submit(GemmRequest::new(0, a, b)));
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    }
    let snap = c.metrics().snapshot();
    assert_eq!(snap.engine_batched, 16);
    assert!(
        snap.engine_flushes < 16,
        "burst must be bucketed, not served one-by-one ({})",
        snap.report()
    );
    c.shutdown();
}

#[test]
fn non_square_requests_still_fall_back_without_artifacts() {
    let c = engine_only_coordinator();
    let mut rng = Rng::new(13);
    let a = uniform_matrix(&mut rng, 48, 80, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 80, 32, -1.0, 1.0);
    let want = mixed_gemm(&a, &b, None, 1.0, 0.0);
    let resp = c.gemm(a, b).unwrap();
    assert_eq!(resp.served_by, ServedBy::CpuFallback);
    assert_eq!(resp.c, want);
    let snap = c.metrics().snapshot();
    assert_eq!(snap.fallback, 1);
    assert_eq!(snap.engine_batched, 0);
    assert_eq!(snap.engine_view_bytes, 0);
    c.shutdown();
}

#[test]
fn pm16_inputs_budget_escalates_precision() {
    // the §VII-B scenario as service behaviour: same budget, ±16 inputs
    // -> the policy must refine
    let Some(c) = coordinator() else { return };
    let mut rng = Rng::new(9);
    let n = 512;
    let a = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let b = uniform_matrix(&mut rng, n, n, -16.0, 16.0);
    let resp = c
        .gemm_with(
            GemmRequest::new(0, a.clone(), b.clone())
                .with_error_budget(0.05)
                .with_scale(16.0),
        )
        .unwrap();
    assert_ne!(resp.mode, RefineMode::None, "±16 inputs must trigger refinement");
    c.shutdown();
}
