//! Engine-vs-oracle equivalence suite: the packed multithreaded engine
//! must reproduce the serial scalar kernels **bit for bit** at every
//! precision mode, for every shape (including degenerate and
//! non-block-multiple ones), at every worker count.  This is the contract
//! that lets every consumer — interfaces, tcemu, refinement, coordinator
//! fallback — ride the fast core without any numerical drift.

use tensoremu::gemm::engine::{
    self, InputPrecision, PackedA, PackedB, PackedHalfA, PackedHalfB,
};
use tensoremu::gemm::{
    batched_hgemm, batched_hgemm_scalar, batched_mixed_gemm, batched_mixed_gemm_scalar,
    batched_sgemm, batched_sgemm_scalar, hgemm, hgemm_scalar, mixed_gemm, mixed_gemm_scalar,
    sgemm_blocked, sgemm_naive, Matrix,
};
use tensoremu::workload::{uniform_matrix, Rng};

/// (m, k, n) shapes: degenerate, tiny, non-block-multiple, block-aligned.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (0, 5, 4),
    (4, 0, 3),
    (3, 4, 0),
    (1, 17, 1),
    (2, 3, 2),
    (5, 7, 3),
    (4, 8, 8),
    (16, 16, 16),
    (17, 16, 15),
    (33, 1, 9),
    (70, 33, 81),
    (64, 64, 64),
    (128, 32, 96),
];

const THREADS: &[usize] = &[1, 2, 8];

fn pair(rng: &mut Rng, m: usize, k: usize, n: usize, scale: f32) -> (Matrix, Matrix) {
    (
        uniform_matrix(rng, m, k, -scale, scale),
        uniform_matrix(rng, k, n, -scale, scale),
    )
}

#[test]
fn mixed_gemm_bitwise_equals_scalar_for_all_shapes_and_threads() {
    let mut rng = Rng::new(1);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
        for &t in THREADS {
            let got = engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t);
            assert_eq!(got, want, "mixed ({m},{k},{n}) threads={t}");
        }
        // the public wrapper (auto threads) as well
        assert_eq!(mixed_gemm(&a, &b, None, 1.0, 0.0), want, "wrapper ({m},{k},{n})");
    }
}

#[test]
fn sgemm_bitwise_equals_naive_for_all_shapes_and_threads() {
    let mut rng = Rng::new(2);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = sgemm_naive(&a, &b, None, 1.0, 0.0);
        for &t in THREADS {
            let got = engine::sgemm(&a, &b, None, 1.0, 0.0, t);
            assert_eq!(got, want, "sgemm ({m},{k},{n}) threads={t}");
        }
        assert_eq!(sgemm_blocked(&a, &b, None, 1.0, 0.0), want, "blocked ({m},{k},{n})");
    }
}

#[test]
fn hgemm_bitwise_equals_scalar_for_all_shapes_and_threads() {
    let mut rng = Rng::new(3);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = hgemm_scalar(&a, &b);
        for &t in THREADS {
            assert_eq!(engine::hgemm(&a, &b, t), want, "hgemm ({m},{k},{n}) threads={t}");
        }
        assert_eq!(hgemm(&a, &b), want, "wrapper ({m},{k},{n})");
    }
}

#[test]
fn alpha_beta_c_epilogue_bitwise() {
    let mut rng = Rng::new(4);
    for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (70, 33, 81)] {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let c = uniform_matrix(&mut rng, m, n, -1.0, 1.0);
        for &(alpha, beta) in &[(1.0f32, 1.0f32), (0.5, 2.0), (-1.25, 0.0), (0.0, 3.0)] {
            let want = mixed_gemm_scalar(&a, &b, Some(&c), alpha, beta);
            for &t in THREADS {
                let got = engine::mixed_gemm(&a, &b, Some(&c), alpha, beta, t);
                assert_eq!(got, want, "({m},{k},{n}) a={alpha} b={beta} t={t}");
            }
        }
    }
}

#[test]
fn absorption_case_k4096_bitwise() {
    // the §V absorption pathology: 4096 ones accumulated in f16 saturate
    // near 2048; in f32 they are exact.  The engine must reproduce the
    // scalar kernels' bits on this pathological chain too.
    let n = 4096;
    let a = Matrix::from_fn(1, n, |_, _| 1.0);
    let b = Matrix::from_fn(n, 1, |_, _| 1.0);
    let h_want = hgemm_scalar(&a, &b);
    let m_want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
    assert!(h_want[(0, 0)] <= 2048.0);
    assert_eq!(m_want[(0, 0)], n as f32);
    for &t in THREADS {
        assert_eq!(engine::hgemm(&a, &b, t), h_want, "hgemm t={t}");
        assert_eq!(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t), m_want, "mixed t={t}");
    }
}

#[test]
fn pm16_range_bitwise() {
    // the paper's ±16 input protocol stresses rounding; bitwise equality
    // must hold there as well
    let mut rng = Rng::new(5);
    let (a, b) = pair(&mut rng, 48, 48, 48, 16.0);
    assert_eq!(
        engine::mixed_gemm(&a, &b, None, 1.0, 0.0, 4),
        mixed_gemm_scalar(&a, &b, None, 1.0, 0.0)
    );
    assert_eq!(engine::hgemm(&a, &b, 4), hgemm_scalar(&a, &b));
}

#[test]
fn determinism_across_worker_counts() {
    // large enough that auto mode would actually parallelize; explicit
    // counts must all produce identical bits
    let mut rng = Rng::new(6);
    let (a, b) = pair(&mut rng, 200, 150, 170, 1.0);
    let base_mixed = engine::mixed_gemm(&a, &b, None, 1.0, 0.0, 1);
    let base_sgemm = engine::sgemm(&a, &b, None, 1.0, 0.0, 1);
    let base_hgemm = engine::hgemm(&a, &b, 1);
    for &t in &[2usize, 3, 5, 8] {
        assert_eq!(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t), base_mixed, "mixed t={t}");
        assert_eq!(engine::sgemm(&a, &b, None, 1.0, 0.0, t), base_sgemm, "sgemm t={t}");
        assert_eq!(engine::hgemm(&a, &b, t), base_hgemm, "hgemm t={t}");
    }
}

#[test]
fn batched_bitwise_equals_scalar_loops() {
    let mut rng = Rng::new(7);
    // heterogeneous shapes in one batch: the engine must handle per-entry
    // shapes, not just uniform tiles
    let mut a = Vec::new();
    let mut b = Vec::new();
    for &(m, k, n) in &[(16, 16, 16), (1, 1, 1), (5, 7, 3), (16, 16, 16), (33, 2, 9)] {
        let (x, y) = pair(&mut rng, m, k, n, 1.0);
        a.push(x);
        b.push(y);
    }
    assert_eq!(batched_mixed_gemm(&a, &b), batched_mixed_gemm_scalar(&a, &b));
    assert_eq!(batched_sgemm(&a, &b), batched_sgemm_scalar(&a, &b));
    assert_eq!(batched_hgemm(&a, &b), batched_hgemm_scalar(&a, &b));
}

#[test]
fn batched_determinism_across_worker_counts() {
    let mut rng = Rng::new(8);
    let a: Vec<Matrix> = (0..65).map(|_| uniform_matrix(&mut rng, 16, 16, -1.0, 1.0)).collect();
    let b: Vec<Matrix> = (0..65).map(|_| uniform_matrix(&mut rng, 16, 16, -1.0, 1.0)).collect();
    let base = engine::batched_mixed_gemm(&a, &b, 1);
    for &t in &[2usize, 8] {
        assert_eq!(engine::batched_mixed_gemm(&a, &b, t), base, "t={t}");
        assert_eq!(engine::batched_hgemm(&a, &b, t), engine::batched_hgemm(&a, &b, 1), "h t={t}");
    }
    // and batched == loop of singles, the Fig. 7 contract
    for i in [0usize, 31, 64] {
        assert_eq!(base[i], mixed_gemm(&a[i], &b[i], None, 1.0, 0.0), "entry {i}");
    }
}

#[test]
fn empty_batch_and_zero_entries() {
    assert!(batched_mixed_gemm(&[], &[]).is_empty());
    let a = vec![Matrix::zeros(0, 4), Matrix::zeros(2, 0)];
    let b = vec![Matrix::zeros(4, 2), Matrix::zeros(0, 3)];
    let got = batched_mixed_gemm(&a, &b);
    assert_eq!(got[0].shape(), (0, 2));
    assert_eq!(got[1], Matrix::zeros(2, 3));
}

#[test]
fn prepacked_operands_reused_across_products() {
    // pack once, multiply many: results must equal fresh packs bitwise
    let mut rng = Rng::new(9);
    let b = uniform_matrix(&mut rng, 40, 24, -1.0, 1.0);
    let pb = PackedB::pack(&b, InputPrecision::F16Rounded);
    for seed in 10..14 {
        let mut r2 = Rng::new(seed);
        let a = uniform_matrix(&mut r2, 31, 40, -1.0, 1.0);
        let pa = PackedA::pack(&a, InputPrecision::F16Rounded);
        let got = engine::gemm_packed(&pa, &pb, None, 1.0, 0.0, 2);
        assert_eq!(got, mixed_gemm_scalar(&a, &b, None, 1.0, 0.0), "seed {seed}");
    }
}

#[test]
fn prepacked_half_operands_reused() {
    let mut rng = Rng::new(15);
    let b = uniform_matrix(&mut rng, 24, 18, -1.0, 1.0);
    let pb = PackedHalfB::pack(&b);
    assert_eq!(pb.shape(), (24, 18));
    for seed in 16..19 {
        let mut r2 = Rng::new(seed);
        let a = uniform_matrix(&mut r2, 13, 24, -1.0, 1.0);
        let pa = PackedHalfA::pack(&a);
        let got = engine::hgemm_packed(&pa, &pb, 2);
        assert_eq!(got, hgemm_scalar(&a, &b), "seed {seed}");
    }
}

#[test]
fn repack_reuse_matches_fresh_pack() {
    let mut rng = Rng::new(20);
    let mut pa = PackedA::default();
    let mut pb = PackedB::default();
    for &(m, k, n) in &[(16, 16, 16), (3, 9, 5), (40, 12, 40)] {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        pa.repack(&a, InputPrecision::F16Rounded);
        pb.repack(&b, InputPrecision::F16Rounded);
        let got = engine::gemm_packed(&pa, &pb, None, 1.0, 0.0, 1);
        assert_eq!(got, mixed_gemm_scalar(&a, &b, None, 1.0, 0.0), "({m},{k},{n})");
    }
}
