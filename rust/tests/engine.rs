//! Engine-vs-oracle equivalence suite: the packed multithreaded engine
//! must reproduce the serial scalar kernels **bit for bit** at every
//! precision mode, for every shape (including degenerate,
//! non-block-multiple, and kc/mc cache-blocked ones), at every worker
//! count, under both pool modes (warm persistent pool and scoped
//! spawns).  This is the contract that lets every consumer — interfaces,
//! tcemu, refinement, coordinator fallback — ride the fast core without
//! any numerical drift.

use tensoremu::gemm::engine::{
    self, InputPrecision, PackedA, PackedB, PackedHalfA, PackedHalfB, PoolMode,
};
use tensoremu::gemm::{
    batched_hgemm, batched_hgemm_scalar, batched_mixed_gemm, batched_mixed_gemm_scalar,
    batched_sgemm, batched_sgemm_scalar, hgemm, hgemm_scalar, mixed_gemm, mixed_gemm_scalar,
    sgemm_blocked, sgemm_naive, Matrix,
};
use tensoremu::workload::{uniform_matrix, Rng};

/// (m, k, n) shapes: degenerate, tiny, non-block-multiple, block-aligned.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (0, 5, 4),
    (4, 0, 3),
    (3, 4, 0),
    (1, 17, 1),
    (2, 3, 2),
    (5, 7, 3),
    (4, 8, 8),
    (16, 16, 16),
    (17, 16, 15),
    (33, 1, 9),
    (70, 33, 81),
    (64, 64, 64),
    (128, 32, 96),
];

const THREADS: &[usize] = &[1, 2, 8];

/// Serializes the tests that flip the process-global pool mode, so each
/// actually exercises the substrate it claims (a concurrent flip can't
/// change bits — that's the contract — but would silently shrink what
/// the warm-pool / scoped-equivalence tests cover).
static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock_mode() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn pair(rng: &mut Rng, m: usize, k: usize, n: usize, scale: f32) -> (Matrix, Matrix) {
    (
        uniform_matrix(rng, m, k, -scale, scale),
        uniform_matrix(rng, k, n, -scale, scale),
    )
}

#[test]
fn mixed_gemm_bitwise_equals_scalar_for_all_shapes_and_threads() {
    let mut rng = Rng::new(1);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
        for &t in THREADS {
            let got = engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t);
            assert_eq!(got, want, "mixed ({m},{k},{n}) threads={t}");
        }
        // the public wrapper (auto threads) as well
        assert_eq!(mixed_gemm(&a, &b, None, 1.0, 0.0), want, "wrapper ({m},{k},{n})");
    }
}

#[test]
fn sgemm_bitwise_equals_naive_for_all_shapes_and_threads() {
    let mut rng = Rng::new(2);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = sgemm_naive(&a, &b, None, 1.0, 0.0);
        for &t in THREADS {
            let got = engine::sgemm(&a, &b, None, 1.0, 0.0, t);
            assert_eq!(got, want, "sgemm ({m},{k},{n}) threads={t}");
        }
        assert_eq!(sgemm_blocked(&a, &b, None, 1.0, 0.0), want, "blocked ({m},{k},{n})");
    }
}

#[test]
fn hgemm_bitwise_equals_scalar_for_all_shapes_and_threads() {
    let mut rng = Rng::new(3);
    for &(m, k, n) in SHAPES {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = hgemm_scalar(&a, &b);
        for &t in THREADS {
            assert_eq!(engine::hgemm(&a, &b, t), want, "hgemm ({m},{k},{n}) threads={t}");
        }
        assert_eq!(hgemm(&a, &b), want, "wrapper ({m},{k},{n})");
    }
}

#[test]
fn alpha_beta_c_epilogue_bitwise() {
    let mut rng = Rng::new(4);
    for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (70, 33, 81)] {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let c = uniform_matrix(&mut rng, m, n, -1.0, 1.0);
        for &(alpha, beta) in &[(1.0f32, 1.0f32), (0.5, 2.0), (-1.25, 0.0), (0.0, 3.0)] {
            let want = mixed_gemm_scalar(&a, &b, Some(&c), alpha, beta);
            for &t in THREADS {
                let got = engine::mixed_gemm(&a, &b, Some(&c), alpha, beta, t);
                assert_eq!(got, want, "({m},{k},{n}) a={alpha} b={beta} t={t}");
            }
        }
    }
}

#[test]
fn absorption_case_k4096_bitwise() {
    // the §V absorption pathology: 4096 ones accumulated in f16 saturate
    // near 2048; in f32 they are exact.  The engine must reproduce the
    // scalar kernels' bits on this pathological chain too.
    let n = 4096;
    let a = Matrix::from_fn(1, n, |_, _| 1.0);
    let b = Matrix::from_fn(n, 1, |_, _| 1.0);
    let h_want = hgemm_scalar(&a, &b);
    let m_want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
    assert!(h_want[(0, 0)] <= 2048.0);
    assert_eq!(m_want[(0, 0)], n as f32);
    for &t in THREADS {
        assert_eq!(engine::hgemm(&a, &b, t), h_want, "hgemm t={t}");
        assert_eq!(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t), m_want, "mixed t={t}");
    }
}

#[test]
fn pm16_range_bitwise() {
    // the paper's ±16 input protocol stresses rounding; bitwise equality
    // must hold there as well
    let mut rng = Rng::new(5);
    let (a, b) = pair(&mut rng, 48, 48, 48, 16.0);
    assert_eq!(
        engine::mixed_gemm(&a, &b, None, 1.0, 0.0, 4),
        mixed_gemm_scalar(&a, &b, None, 1.0, 0.0)
    );
    assert_eq!(engine::hgemm(&a, &b, 4), hgemm_scalar(&a, &b));
}

#[test]
fn determinism_across_worker_counts() {
    // large enough that auto mode would actually parallelize; explicit
    // counts must all produce identical bits
    let mut rng = Rng::new(6);
    let (a, b) = pair(&mut rng, 200, 150, 170, 1.0);
    let base_mixed = engine::mixed_gemm(&a, &b, None, 1.0, 0.0, 1);
    let base_sgemm = engine::sgemm(&a, &b, None, 1.0, 0.0, 1);
    let base_hgemm = engine::hgemm(&a, &b, 1);
    for &t in &[2usize, 3, 5, 8] {
        assert_eq!(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t), base_mixed, "mixed t={t}");
        assert_eq!(engine::sgemm(&a, &b, None, 1.0, 0.0, t), base_sgemm, "sgemm t={t}");
        assert_eq!(engine::hgemm(&a, &b, t), base_hgemm, "hgemm t={t}");
    }
}

#[test]
fn batched_bitwise_equals_scalar_loops() {
    let mut rng = Rng::new(7);
    // heterogeneous shapes in one batch: the engine must handle per-entry
    // shapes, not just uniform tiles
    let mut a = Vec::new();
    let mut b = Vec::new();
    for &(m, k, n) in &[(16, 16, 16), (1, 1, 1), (5, 7, 3), (16, 16, 16), (33, 2, 9)] {
        let (x, y) = pair(&mut rng, m, k, n, 1.0);
        a.push(x);
        b.push(y);
    }
    assert_eq!(batched_mixed_gemm(&a, &b), batched_mixed_gemm_scalar(&a, &b));
    assert_eq!(batched_sgemm(&a, &b), batched_sgemm_scalar(&a, &b));
    assert_eq!(batched_hgemm(&a, &b), batched_hgemm_scalar(&a, &b));
}

#[test]
fn batched_determinism_across_worker_counts() {
    let mut rng = Rng::new(8);
    let a: Vec<Matrix> = (0..65).map(|_| uniform_matrix(&mut rng, 16, 16, -1.0, 1.0)).collect();
    let b: Vec<Matrix> = (0..65).map(|_| uniform_matrix(&mut rng, 16, 16, -1.0, 1.0)).collect();
    let base = engine::batched_mixed_gemm(&a, &b, 1);
    for &t in &[2usize, 8] {
        assert_eq!(engine::batched_mixed_gemm(&a, &b, t), base, "t={t}");
        assert_eq!(engine::batched_hgemm(&a, &b, t), engine::batched_hgemm(&a, &b, 1), "h t={t}");
    }
    // and batched == loop of singles, the Fig. 7 contract
    for i in [0usize, 31, 64] {
        assert_eq!(base[i], mixed_gemm(&a[i], &b[i], None, 1.0, 0.0), "entry {i}");
    }
}

#[test]
fn empty_batch_and_zero_entries() {
    assert!(batched_mixed_gemm(&[], &[]).is_empty());
    let a = vec![Matrix::zeros(0, 4), Matrix::zeros(2, 0)];
    let b = vec![Matrix::zeros(4, 2), Matrix::zeros(0, 3)];
    let got = batched_mixed_gemm(&a, &b);
    assert_eq!(got[0].shape(), (0, 2));
    assert_eq!(got[1], Matrix::zeros(2, 3));
}

#[test]
fn prepacked_operands_reused_across_products() {
    // pack once, multiply many: results must equal fresh packs bitwise
    let mut rng = Rng::new(9);
    let b = uniform_matrix(&mut rng, 40, 24, -1.0, 1.0);
    let pb = PackedB::pack(&b, InputPrecision::F16Rounded);
    for seed in 10..14 {
        let mut r2 = Rng::new(seed);
        let a = uniform_matrix(&mut r2, 31, 40, -1.0, 1.0);
        let pa = PackedA::pack(&a, InputPrecision::F16Rounded);
        let got = engine::gemm_packed(&pa, &pb, None, 1.0, 0.0, 2);
        assert_eq!(got, mixed_gemm_scalar(&a, &b, None, 1.0, 0.0), "seed {seed}");
    }
}

#[test]
fn prepacked_half_operands_reused() {
    let mut rng = Rng::new(15);
    let b = uniform_matrix(&mut rng, 24, 18, -1.0, 1.0);
    let pb = PackedHalfB::pack(&b);
    assert_eq!(pb.shape(), (24, 18));
    for seed in 16..19 {
        let mut r2 = Rng::new(seed);
        let a = uniform_matrix(&mut r2, 13, 24, -1.0, 1.0);
        let pa = PackedHalfA::pack(&a);
        let got = engine::hgemm_packed(&pa, &pb, 2);
        assert_eq!(got, hgemm_scalar(&a, &b), "seed {seed}");
    }
}

#[test]
fn kc_blocked_long_k_bitwise_70x33x4096() {
    // k = 4096 spans 16 kc blocks: the C-resident accumulator tile is
    // spilled and reloaded 15 times per output element, and the result
    // must still be the scalar oracle's single ascending-k chain, bit
    // for bit, at every worker count
    let mut rng = Rng::new(30);
    let (a, b) = pair(&mut rng, 70, 4096, 33, 1.0);
    let want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
    for &t in THREADS {
        assert_eq!(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t), want, "t={t}");
    }
}

#[test]
fn mc_and_kc_blocked_mid_shape_bitwise() {
    // m spans multiple mc row blocks per worker and k multiple kc
    // blocks, with ragged edges on every dimension
    let mut rng = Rng::new(31);
    let (a, b) = pair(&mut rng, 300, 600, 65, 1.0);
    let want = sgemm_naive(&a, &b, None, 1.0, 0.0);
    for &t in &[1usize, 3] {
        assert_eq!(engine::sgemm(&a, &b, None, 1.0, 0.0, t), want, "t={t}");
    }
}

#[test]
fn warm_persistent_pool_repeated_calls_bitwise_stable() {
    // repeated, interleaved shapes on an increasingly warm pool: worker
    // reuse must never perturb a bit at any worker count.  The ambient
    // mode is restored afterwards so the TENSOREMU_POOL=scoped CI leg
    // keeps covering the scoped substrate in later tests.
    let _g = lock_mode();
    let ambient = engine::pool_mode();
    engine::set_pool_mode(PoolMode::Persistent);
    let mut rng = Rng::new(32);
    let shapes = [(70, 33, 81), (16, 16, 16), (40, 24, 40)];
    let inputs: Vec<_> = shapes.iter().map(|&(m, k, n)| pair(&mut rng, m, k, n, 1.0)).collect();
    let want: Vec<_> =
        inputs.iter().map(|(a, b)| mixed_gemm_scalar(a, b, None, 1.0, 0.0)).collect();
    for round in 0..3 {
        for (i, (a, b)) in inputs.iter().enumerate() {
            for &t in THREADS {
                assert_eq!(
                    engine::mixed_gemm(a, b, None, 1.0, 0.0, t),
                    want[i],
                    "round={round} shape#{i} t={t}"
                );
            }
        }
    }
    engine::set_pool_mode(ambient);
}

#[test]
fn scoped_and_persistent_pools_produce_identical_bits() {
    // the pool mode is an execution-substrate knob only: both modes run
    // the same static partition, so the bits cannot differ — on an
    // unblocked small shape and on a kc-blocked one (k > KC), at every
    // worker count
    let _g = lock_mode();
    let ambient = engine::pool_mode();
    let mut rng = Rng::new(33);
    for &(m, k, n) in &[(40, 24, 40), (70, 600, 33)] {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        let want = mixed_gemm_scalar(&a, &b, None, 1.0, 0.0);
        let hwant = hgemm_scalar(&a, &b);
        for mode in [PoolMode::Scoped, PoolMode::Persistent] {
            engine::set_pool_mode(mode);
            for &t in THREADS {
                let got = engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t);
                assert_eq!(got, want, "({m},{k},{n}) {mode:?} t={t}");
                assert_eq!(engine::hgemm(&a, &b, t), hwant, "hgemm ({m},{k},{n}) {mode:?} t={t}");
            }
        }
    }
    // restore the ambient mode (TENSOREMU_POOL-selected), not a
    // hardcoded one — the scoped CI leg relies on it
    engine::set_pool_mode(ambient);
}

#[test]
fn env_knobs_are_exposed_and_sane() {
    // TENSOREMU_THREADS / TENSOREMU_POOL handling: the exhaustive parser
    // cases live next to the parsers (pool.rs::env_value_parsers); here
    // just pin the public re-exports and the resolved defaults
    use tensoremu::gemm::engine::{parse_pool_mode, parse_threads};
    assert_eq!(parse_threads(Some("8")), Some(8));
    assert_eq!(parse_pool_mode(Some("scoped")), PoolMode::Scoped);
    assert_eq!(parse_pool_mode(None), PoolMode::Persistent);
    assert!(engine::default_threads() >= 1);
}

#[test]
fn repack_reuse_matches_fresh_pack() {
    let mut rng = Rng::new(20);
    let mut pa = PackedA::default();
    let mut pb = PackedB::default();
    for &(m, k, n) in &[(16, 16, 16), (3, 9, 5), (40, 12, 40)] {
        let (a, b) = pair(&mut rng, m, k, n, 1.0);
        pa.repack(&a, InputPrecision::F16Rounded);
        pb.repack(&b, InputPrecision::F16Rounded);
        let got = engine::gemm_packed(&pa, &pb, None, 1.0, 0.0, 1);
        assert_eq!(got, mixed_gemm_scalar(&a, &b, None, 1.0, 0.0), "({m},{k},{n})");
    }
}
