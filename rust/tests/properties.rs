//! Property-based tests over the crate's numerical invariants, driven by
//! the in-tree property harness (util::prop — the offline image has no
//! proptest; failures print a replayable seed).

use tensoremu::ensure_prop;
use tensoremu::gemm::engine::{sparse24_check, sparse24_prune, Sparse24};
use tensoremu::gemm::{batched_mixed_gemm, dgemm_naive, mixed_gemm, sgemm_blocked, sgemm_naive, Matrix};
use tensoremu::halfprec::{f16_to_f32, f32_to_f16, split_residual, ulp_at, Half};
use tensoremu::interfaces::{wmma_tiled_gemm, CutlassGemm, TilePolicy};
use tensoremu::precision::bounds::mixed_gemm_error_bound;
use tensoremu::precision::{refine_gemm, RefineMode};
use tensoremu::util::prop::forall;
use tensoremu::workload::{uniform_batch, uniform_matrix, Rng};

fn rand_dims(rng: &mut Rng) -> (usize, usize, usize) {
    let pick = |rng: &mut Rng| 16 * (1 + rng.below(6));
    (pick(rng), pick(rng), pick(rng))
}

#[test]
fn prop_f16_roundtrip_error_below_half_ulp() {
    forall(200, |rng| {
        let x = rng.uniform(-60000.0, 60000.0);
        let h = f32_to_f16(x);
        let err = (x - f16_to_f32(h)).abs();
        let bound = ulp_at(x) / 2.0 + f32::EPSILON * x.abs();
        ensure_prop!(err <= bound, "x={x} err={err} bound={bound}");
        Ok(())
    });
}

#[test]
fn prop_f16_rounding_monotone() {
    // rounding preserves order: x <= y => f16(x) <= f16(y)
    forall(300, |rng| {
        let x = rng.uniform(-100.0, 100.0);
        let y = rng.uniform(-100.0, 100.0);
        let (lo, hi) = if x <= y { (x, y) } else { (y, x) };
        let (hl, hh) = (f32_to_f16(lo).to_f32(), f32_to_f16(hi).to_f32());
        ensure_prop!(hl <= hh, "monotonicity broke: {lo}->{hl}, {hi}->{hh}");
        Ok(())
    });
}

#[test]
fn prop_residual_split_reconstructs() {
    forall(300, |rng| {
        let scale = [1.0f32, 16.0, 100.0][rng.below(3)];
        let x = rng.uniform(-scale, scale);
        let s = split_residual(x);
        let leak = (x - s.reconstruct()).abs();
        // leak bounded by half an ulp of the residual's magnitude
        let bound = ulp_at(ulp_at(x) / 2.0) / 2.0 + f32::EPSILON;
        ensure_prop!(leak <= bound.max(1e-12), "x={x} leak={leak} bound={bound}");
        Ok(())
    });
}

#[test]
fn prop_residual_hi_is_rounding() {
    forall(200, |rng| {
        let x = rng.uniform(-1000.0, 1000.0);
        ensure_prop!(split_residual(x).hi == f32_to_f16(x), "hi != f16(x) at {x}");
        Ok(())
    });
}

#[test]
fn prop_mixed_gemm_error_within_analytic_bound() {
    forall(25, |rng| {
        let (m, n, k) = rand_dims(rng);
        let scale = [1.0f32, 4.0][rng.below(2)];
        let a = uniform_matrix(rng, m, k, -scale, scale);
        let b = uniform_matrix(rng, k, n, -scale, scale);
        let got = mixed_gemm(&a, &b, None, 1.0, 0.0);
        let truth = dgemm_naive(&a, &b);
        let err = got.max_norm_diff(&truth);
        let bound = mixed_gemm_error_bound(k, scale);
        ensure_prop!(err <= bound, "({m},{n},{k}) scale {scale}: err {err} > bound {bound}");
        Ok(())
    });
}

#[test]
fn prop_refinement_never_hurts() {
    forall(20, |rng| {
        let n = 16 * (1 + rng.below(4));
        let scale = [1.0f32, 16.0][rng.below(2)];
        let a = uniform_matrix(rng, n, n, -scale, scale);
        let b = uniform_matrix(rng, n, n, -scale, scale);
        let truth = dgemm_naive(&a, &b);
        let e0 = refine_gemm(&a, &b, RefineMode::None).max_norm_diff(&truth);
        let e1 = refine_gemm(&a, &b, RefineMode::RefineA).max_norm_diff(&truth);
        let e2 = refine_gemm(&a, &b, RefineMode::RefineAB).max_norm_diff(&truth);
        // refine_a gets a 15% statistical allowance: it can shift which
        // entry attains the max norm (B's error remains); refine_ab
        // removes both inputs' errors and must land far below
        ensure_prop!(e1 <= e0 * 1.15, "refine_a hurt: {e0} -> {e1}");
        ensure_prop!(e2 <= e1 * 0.5, "refine_ab too weak: {e1} -> {e2}");
        Ok(())
    });
}

#[test]
fn prop_all_gemm_backends_agree() {
    // wmma-tiled, cutlass (any policy) and the scalar oracle are the
    // same function, bit for bit
    forall(15, |rng| {
        let (m, n, k) = rand_dims(rng);
        let a = uniform_matrix(rng, m, k, -1.0, 1.0);
        let b = uniform_matrix(rng, k, n, -1.0, 1.0);
        let oracle = mixed_gemm(&a, &b, None, 1.0, 0.0);
        let wmma = wmma_tiled_gemm(&a, &b);
        ensure_prop!(wmma == oracle, "wmma != oracle at ({m},{n},{k})");
        let policy = TilePolicy::SWEEP[rng.below(TilePolicy::SWEEP.len())];
        let ct = CutlassGemm::new(policy).run(&a, &b);
        ensure_prop!(ct == oracle, "cutlass {policy:?} != oracle at ({m},{n},{k})");
        Ok(())
    });
}

#[test]
fn prop_sgemm_blocked_close_to_naive() {
    forall(20, |rng| {
        let (m, n, k) = rand_dims(rng);
        let a = uniform_matrix(rng, m, k, -1.0, 1.0);
        let b = uniform_matrix(rng, k, n, -1.0, 1.0);
        let d = sgemm_blocked(&a, &b, None, 1.0, 0.0)
            .max_norm_diff(&sgemm_naive(&a, &b, None, 1.0, 0.0));
        // only accumulation-order noise
        ensure_prop!(d <= 1e-4 * k as f32 / 16.0, "({m},{n},{k}): diff {d}");
        Ok(())
    });
}

#[test]
fn prop_batched_equals_loop_of_singles() {
    forall(10, |rng| {
        let count = 1 + rng.below(8);
        let n = 8 * (1 + rng.below(3));
        let a = uniform_batch(rng, count, n, -1.0, 1.0);
        let b = uniform_batch(rng, count, n, -1.0, 1.0);
        let batched = batched_mixed_gemm(&a, &b);
        for i in 0..count {
            let single = mixed_gemm(&a[i], &b[i], None, 1.0, 0.0);
            ensure_prop!(batched[i] == single, "entry {i} differs");
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_linearity_in_alpha() {
    // sgemm(alpha) == alpha * sgemm(1) for exact scalars
    forall(20, |rng| {
        let n = 16 * (1 + rng.below(3));
        let a = uniform_matrix(rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(rng, n, n, -1.0, 1.0);
        let one = sgemm_naive(&a, &b, None, 1.0, 0.0);
        let two = sgemm_naive(&a, &b, None, 2.0, 0.0);
        let scaled = Matrix::from_fn(n, n, |i, j| 2.0 * one[(i, j)]);
        ensure_prop!(two == scaled, "alpha scaling broke");
        Ok(())
    });
}

#[test]
fn prop_half_arithmetic_commutative() {
    forall(300, |rng| {
        let a = f32_to_f16(rng.uniform(-100.0, 100.0));
        let b = f32_to_f16(rng.uniform(-100.0, 100.0));
        ensure_prop!(
            tensoremu::halfprec::half_add(a, b) == tensoremu::halfprec::half_add(b, a),
            "add not commutative"
        );
        ensure_prop!(
            tensoremu::halfprec::half_mul(a, b) == tensoremu::halfprec::half_mul(b, a),
            "mul not commutative"
        );
        Ok(())
    });
}

#[test]
fn prop_half_special_values() {
    forall(100, |rng| {
        let x = rng.uniform(-65000.0, 65000.0);
        let h = f32_to_f16(x);
        ensure_prop!(!h.is_nan(), "finite input became NaN: {x}");
        // negation is a bit flip
        ensure_prop!(f32_to_f16(-x) == h.neg() || x == 0.0, "neg mismatch at {x}");
        Ok(())
    });
}

#[test]
fn prop_zero_times_anything_is_zero() {
    forall(50, |rng| {
        let n = 16 * (1 + rng.below(3));
        let a = uniform_matrix(rng, n, n, -1e4, 1e4);
        let z = Matrix::zeros(n, n);
        let c = mixed_gemm(&a, &z, None, 1.0, 0.0);
        ensure_prop!(c == Matrix::zeros(n, n), "A x 0 != 0");
        Ok(())
    });
}

#[test]
fn prop_overflow_saturates_to_infinity_not_garbage() {
    // §V: values above 65504 become half infinity; the GEMM must then
    // produce inf/nan, never silently wrong finite numbers
    forall(30, |rng| {
        let n = 16;
        let mut a = uniform_matrix(rng, n, n, -1.0, 1.0);
        a[(0, 0)] = 1e30; // rounds to +inf in f16
        let b = Matrix::eye(n);
        let c = mixed_gemm(&a, &b, None, 1.0, 0.0);
        ensure_prop!(c[(0, 0)].is_infinite(), "expected inf, got {}", c[(0, 0)]);
        Ok(())
    });
}

/// Dims for the sparsity properties: small odd shapes so `k % 4` hits
/// every tail width, not just the group-aligned case.
fn rand_sparse_dims(rng: &mut Rng) -> (usize, usize) {
    (1 + rng.below(24), 1 + rng.below(40))
}

#[test]
fn prop_sparse24_prune_keeps_at_most_two_per_group() {
    forall(100, |rng| {
        let (m, k) = rand_sparse_dims(rng);
        let a = uniform_matrix(rng, m, k, -4.0, 4.0);
        let p = sparse24_prune(&a);
        ensure_prop!(
            sparse24_check(&(&p).into()).is_ok(),
            "pruned image fails the 2:4 structural check at ({m},{k})"
        );
        for i in 0..m {
            for g in 0..(k + 3) / 4 {
                let w = (k - g * 4).min(4);
                let nz = (0..w).filter(|&l| p[(i, g * 4 + l)] != 0.0).count();
                ensure_prop!(nz <= 2, "row {i} group {g}: {nz} nonzeros survive pruning");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse24_kept_lanes_are_the_top2_by_magnitude() {
    // The deterministic tie rule: equal magnitudes keep the *earlier*
    // lane.  So every dropped lane is either strictly smaller in
    // magnitude than the weakest kept lane, or ties a kept lane that
    // sits at a strictly earlier index.  Values are snapped to a
    // coarse grid so magnitude ties actually occur.
    forall(100, |rng| {
        let (m, k) = rand_sparse_dims(rng);
        let raw = uniform_matrix(rng, m, k, -2.0, 2.0);
        let a = Matrix::from_fn(m, k, |i, j| (raw[(i, j)] * 4.0).round() / 4.0);
        let s = Sparse24::compress(&a);
        let groups = (k + 3) / 4;
        for i in 0..m {
            for g in 0..groups {
                let w = (k - g * 4).min(4);
                let mb = s.meta()[i * groups + g];
                let (i0, i1) = ((mb & 3) as usize, ((mb >> 2) & 3) as usize);
                ensure_prop!(i0 < w && i1 < w, "meta names lane outside width-{w} group");
                let weakest = a[(i, g * 4 + i1)].abs().min(a[(i, g * 4 + i0)].abs());
                for l in 0..w {
                    if l == i0 || l == i1 {
                        continue;
                    }
                    let dropped = a[(i, g * 4 + l)].abs();
                    let tied_earlier = [i0, i1]
                        .iter()
                        .any(|&c| a[(i, g * 4 + c)].abs() == dropped && c < l);
                    ensure_prop!(
                        dropped < weakest || tied_earlier,
                        "row {i} group {g}: dropped lane {l} (|{dropped}|) beats kept \
                         pair ({i0},{i1}) with weakest |{weakest}|"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse24_codec_roundtrips_the_pruned_matrix() {
    forall(100, |rng| {
        let (m, k) = rand_sparse_dims(rng);
        let a = uniform_matrix(rng, m, k, -8.0, 8.0);
        let s = Sparse24::compress(&a);
        ensure_prop!(s.shape() == (m, k), "compressed shape mismatch");
        ensure_prop!(
            s.decompress() == sparse24_prune(&a),
            "decompress(compress(a)) != prune(a) at ({m},{k})"
        );
        Ok(())
    });
}

#[test]
fn prop_half_infinity_constant() {
    assert_eq!(f32_to_f16(f32::INFINITY), Half::INFINITY);
    assert_eq!(f32_to_f16(65504.0), Half::MAX);
}
