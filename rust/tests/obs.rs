//! Observability contract suite: the request-lifecycle tracing
//! subsystem against a live coordinator.
//!
//! The contracts pinned here:
//!
//! * **Exact overflow accounting** — a full ring drops events into a
//!   visible counter, never silently: `kept + dropped == pushes`.
//! * **Capture totality** — at sample rate 1 every admitted request
//!   records exactly one `admit` and exactly one terminal event
//!   (`reply`/`shed`/`deadline`/`error`/`shutdown`), under burst,
//!   shed, poison panic and shutdown — the PR 6 reply-totality
//!   identity restated over spans.
//! * **Observation only** — tracing on vs off leaves every reply
//!   bitwise identical, at every pool mode.
//! * **Export validity** — the Chrome trace JSON re-parses with
//!   `util::json` and its accounting block matches the sink.
//! * **Poison tolerance** — panicking a worker mid-span (simulated via
//!   the `#[doc(hidden)]` ring poisoner) cannot wedge recording or
//!   export, mirroring the `Metrics` poison contract.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tensoremu::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, CoordinatorError, GemmRequest, PrecisionMode,
};
use tensoremu::gemm::engine::{self, PoolMode};
use tensoremu::gemm::{fp8e5m2_gemm_scalar, mixed_gemm, Matrix};
use tensoremu::obs::{self, Stage, TraceConfig, TraceEvent, TraceSink};
use tensoremu::runtime::{ExecutorServer, Manifest};
use tensoremu::util::json::Json;
use tensoremu::workload::{uniform_matrix, Rng};

/// Serializes every test here: the sampling knob (and, for the bitwise
/// sweep, the engine pool mode) is process-global state.
static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Engine-only service (empty manifest): every square request rides the
/// bucketed engine lane, so the suite runs without built artifacts.
fn engine_only(cfg: CoordinatorConfig) -> Coordinator {
    let manifest = Manifest { dir: std::path::PathBuf::from("unbuilt"), artifacts: Vec::new() };
    let executor = ExecutorServer::start(manifest).expect("executor over empty manifest");
    Coordinator::start_with(cfg, executor).expect("coordinator over empty manifest")
}

fn traced_cfg() -> CoordinatorConfig {
    CoordinatorConfig { trace: Some(TraceConfig::default()), ..Default::default() }
}

fn count(events: &[TraceEvent], stage: Stage) -> usize {
    events.iter().filter(|e| e.stage == stage).count()
}

fn terminals(events: &[TraceEvent]) -> usize {
    events.iter().filter(|e| e.stage.is_terminal()).count()
}

#[test]
fn ring_overflow_drop_accounting_is_exact() {
    // no coordinator needed: push straight at a tiny sink and account
    // every event — kept + dropped == pushes, per shard and in total
    let sink = TraceSink::for_shards(2, 4);
    let pushes_per_shard = 11u64;
    for shard in 0..2u32 {
        for i in 0..pushes_per_shard {
            sink.push(TraceEvent {
                id: i,
                stage: Stage::Admit,
                detail: "",
                shard,
                worker: 0,
                start_us: i,
                dur_us: 0,
            });
        }
    }
    assert_eq!(sink.events().len(), 8, "2 shards x capacity 4 kept");
    assert_eq!(sink.dropped(), 2 * (pushes_per_shard - 4));
    for (shard, d) in sink.dropped_per_shard().iter().enumerate() {
        assert_eq!(*d, pushes_per_shard - 4, "shard {shard}");
        assert_eq!(
            sink.shard_events(shard).len() as u64 + d,
            pushes_per_shard,
            "shard {shard}: kept + dropped == pushes"
        );
    }
    // the breakdown and export surface the same exact count
    assert_eq!(sink.breakdown().dropped, sink.dropped());
}

#[test]
fn sample_rate_one_captures_every_admitted_request() {
    let _g = lock();
    obs::set_sampling(1);
    let c = engine_only(traced_cfg());
    let mut rng = Rng::new(41);
    let n_requests = 24u64;
    let mut rxs = Vec::new();
    for i in 1..=n_requests {
        let n = [16usize, 24, 33][(i % 3) as usize];
        let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(i, a, b)));
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    }
    let sink = c.trace_sink().expect("traced service exposes its sink");
    c.shutdown();
    obs::set_sampling(0);
    let events = sink.events();
    assert_eq!(sink.dropped(), 0, "default capacity must not drop this load");
    assert_eq!(count(&events, Stage::Admit) as u64, n_requests, "one admit per request");
    assert_eq!(count(&events, Stage::Reply) as u64, n_requests, "one reply per request");
    assert_eq!(terminals(&events) as u64, n_requests, "admits == terminals");
    // the engine lane leaves its whole pipeline in the trace
    assert_eq!(count(&events, Stage::Queued) as u64, n_requests);
    assert_eq!(count(&events, Stage::Bucketed) as u64, n_requests);
    assert!(count(&events, Stage::Flush) >= 1, "at least one bucket flushed");
    assert!(count(&events, Stage::Exec) >= 1, "plan exec spans recorded");
    assert!(count(&events, Stage::Epilogue) >= 1, "batched epilogue spans recorded");
    // every request-scoped event carries its request id, and timestamps
    // are monotonic from one epoch (sorted by construction)
    for w in events.windows(2) {
        assert!(w[0].start_us <= w[1].start_us, "events sorted by start");
    }
}

#[test]
fn burst_shed_poison_and_shutdown_keep_span_totality_exact() {
    let _g = lock();
    obs::set_sampling(1);

    // phase 1 — deterministic sheds + shutdown sheds: a never-flushing
    // service with a tiny admission budget.  Whatever is admitted stays
    // queued (huge batch, huge wait), so every submit past the cap is
    // shed typed, and shutdown answers the queued remainder.
    let c = engine_only(CoordinatorConfig {
        queue_cap: 4,
        shards: 1,
        batcher: BatcherConfig {
            max_batch: 100_000,
            max_wait: Duration::from_secs(100_000),
            ..Default::default()
        },
        ..traced_cfg()
    });
    let mut rng = Rng::new(43);
    let mut rxs = Vec::new();
    for i in 1..=12u64 {
        let a = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(i, a, b)));
    }
    let sink = c.trace_sink().unwrap();
    c.shutdown();
    let mut outcomes = (0u64, 0u64); // (shed, shutdown)
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            Err(CoordinatorError::Shed { .. }) => outcomes.0 += 1,
            Err(CoordinatorError::ShuttingDown) => outcomes.1 += 1,
            other => panic!("expected shed or shutdown, got {other:?}"),
        }
    }
    assert_eq!(outcomes, (8, 4), "cap 4: 4 queued to shutdown, 8 shed");
    let events = sink.events();
    assert_eq!(count(&events, Stage::Admit), 12);
    assert_eq!(count(&events, Stage::Shed), 8);
    assert_eq!(count(&events, Stage::Shutdown), 4);
    assert_eq!(terminals(&events), 12, "admits == terminals under shed + shutdown");
    assert_eq!(sink.dropped(), 0);

    // phase 2 — poison panic + expired deadline + healthy traffic on a
    // flushing service: the panic becomes a typed error with an `error`
    // terminal, the expired request a `deadline` terminal, and healthy
    // replies stay bitwise equal to the oracle while traced.
    let c = engine_only(traced_cfg());
    let pa = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let pb = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let rx_poison = c.submit(GemmRequest::new(100, pa, pb).with_poison());
    let da = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let db = uniform_matrix(&mut rng, 16, 16, -1.0, 1.0);
    let expired = Instant::now() - Duration::from_secs(1);
    let rx_dead = c.submit(GemmRequest::new(101, da, db).with_deadline(expired));
    let ha = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let hb = uniform_matrix(&mut rng, 33, 33, -1.0, 1.0);
    let rx_ok = c.submit(GemmRequest::new(102, ha.clone(), hb.clone()));
    match rx_poison.recv_timeout(Duration::from_secs(30)).unwrap() {
        Err(CoordinatorError::Internal(msg)) => assert!(msg.contains("poison"), "{msg}"),
        other => panic!("expected Internal, got {other:?}"),
    }
    assert_eq!(
        rx_dead.recv_timeout(Duration::from_secs(30)).unwrap().unwrap_err(),
        CoordinatorError::DeadlineExceeded
    );
    let ok = rx_ok.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(ok.c, mixed_gemm(&ha, &hb, None, 1.0, 0.0), "traced reply bitwise == oracle");
    let sink = c.trace_sink().unwrap();
    c.shutdown();
    obs::set_sampling(0);
    let events = sink.events();
    assert_eq!(count(&events, Stage::Admit), 3);
    assert_eq!(count(&events, Stage::Error), 1, "poison panic terminal");
    assert_eq!(count(&events, Stage::Deadline), 1, "expired deadline terminal");
    assert_eq!(count(&events, Stage::Reply), 1);
    assert_eq!(terminals(&events), 3, "admits == terminals under panic + deadline");
}

#[test]
fn tracing_toggle_keeps_replies_bitwise_identical_across_pool_modes() {
    // the observation-only contract: the same inputs through an
    // untraced and a traced service produce bitwise-identical results,
    // at both pool modes, including the new fp8e5m2 format mode
    let _g = lock();
    let ambient = engine::pool_mode();
    let mut rng = Rng::new(47);
    let inputs: Vec<(Matrix, Matrix, Option<PrecisionMode>)> = (0..12)
        .map(|i| {
            let n = [16usize, 24, 33][i % 3];
            let mode = match i % 4 {
                0 => None,
                1 => Some(PrecisionMode::Bf16),
                2 => Some(PrecisionMode::Fp8E5M2),
                _ => Some(PrecisionMode::Tf32),
            };
            (
                uniform_matrix(&mut rng, n, n, -1.0, 1.0),
                uniform_matrix(&mut rng, n, n, -1.0, 1.0),
                mode,
            )
        })
        .collect();
    let run = |cfg: CoordinatorConfig| -> Vec<Matrix> {
        let c = engine_only(cfg);
        let rxs: Vec<_> = inputs
            .iter()
            .enumerate()
            .map(|(i, (a, b, mode))| {
                let mut req = GemmRequest::new(i as u64 + 1, a.clone(), b.clone());
                if let Some(m) = mode {
                    req = req.with_mode(*m);
                }
                c.submit(req)
            })
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap().c)
            .collect();
        c.shutdown();
        out
    };
    for pm in [PoolMode::Scoped, PoolMode::Persistent] {
        engine::set_pool_mode(pm);
        obs::set_sampling(0);
        let plain = run(CoordinatorConfig::default());
        obs::set_sampling(1);
        let traced = run(traced_cfg());
        obs::set_sampling(0);
        assert_eq!(plain, traced, "tracing changed a reply bitwise ({pm:?})");
    }
    engine::set_pool_mode(ambient);
    // and the fp8e5m2 lane itself is oracle-exact: spot-check one pair
    let (a, b, _) = &inputs[2];
    obs::set_sampling(1);
    let c = engine_only(traced_cfg());
    let resp = c
        .gemm_with(GemmRequest::new(0, a.clone(), b.clone()).with_mode(PrecisionMode::Fp8E5M2))
        .unwrap();
    assert_eq!(resp.c, fp8e5m2_gemm_scalar(a, b, None, 1.0, 0.0));
    c.shutdown();
    obs::set_sampling(0);
}

#[test]
fn chrome_export_parses_with_util_json_and_accounts_exactly() {
    let _g = lock();
    obs::set_sampling(1);
    let c = engine_only(traced_cfg());
    let mut rng = Rng::new(53);
    let mut rxs = Vec::new();
    for i in 1..=8u64 {
        let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
        let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
        rxs.push(c.submit(GemmRequest::new(i, a, b)));
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    }
    let sink = c.trace_sink().unwrap();
    c.shutdown();
    obs::set_sampling(0);
    let doc = sink.chrome_json();
    let parsed = Json::parse(&format!("{doc}")).expect("chrome export re-parses");
    let arr = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let data: Vec<&Json> = arr
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) != Some("M"))
        .collect();
    let meta = arr.len() - data.len();
    assert_eq!(data.len(), sink.events().len(), "one data event per recorded event");
    assert!(meta >= 2, "process/thread name metadata present");
    for e in &data {
        assert!(e.get("ts").and_then(Json::as_f64).is_some(), "every event has ts");
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        match ph {
            "X" => assert!(e.get("dur").and_then(Json::as_f64).is_some(), "span has dur"),
            "i" => assert_eq!(e.get("s").and_then(Json::as_str), Some("t")),
            other => panic!("unexpected phase {other:?}"),
        }
    }
    // the non-standard accounting block matches the sink exactly
    let acct = parsed.get("tensoremu").expect("accounting block");
    assert_eq!(acct.get("events").and_then(Json::as_usize), Some(sink.events().len()));
    let dropped = acct.get("dropped").and_then(Json::as_arr).expect("per-shard drops");
    assert_eq!(dropped.len(), sink.shards());
    assert!(dropped.iter().all(|d| d.as_f64() == Some(0.0)), "nothing dropped here");
}

#[test]
fn poisoned_rings_do_not_wedge_recording_or_export() {
    let _g = lock();
    obs::set_sampling(1);
    // a worker that panics while holding a ring lock poisons the mutex;
    // recording and every exporter must shrug it off, like Metrics
    let sink = Arc::new(TraceSink::for_shards(2, 16));
    sink.push(TraceEvent {
        id: 1,
        stage: Stage::Admit,
        detail: "",
        shard: 0,
        worker: 0,
        start_us: 1,
        dur_us: 0,
    });
    sink.poison_rings_for_test();
    sink.push(TraceEvent {
        id: 2,
        stage: Stage::Reply,
        detail: "",
        shard: 0,
        worker: 0,
        start_us: 2,
        dur_us: 5,
    });
    let events = sink.events();
    assert_eq!(events.len(), 2, "pushes before and after the poison both kept");
    assert!(sink.breakdown().row(Stage::Reply).is_some());
    assert!(Json::parse(&format!("{}", sink.chrome_json())).is_ok());

    // and end to end: poisoning a live service's rings mid-traffic
    // cannot wedge later requests or the final export
    let c = engine_only(traced_cfg());
    let mut rng = Rng::new(59);
    let a = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 24, 24, -1.0, 1.0);
    c.gemm(a.clone(), b.clone()).unwrap();
    let live = c.trace_sink().unwrap();
    live.poison_rings_for_test();
    let resp = c.gemm(a.clone(), b.clone()).unwrap();
    assert_eq!(resp.c, mixed_gemm(&a, &b, None, 1.0, 0.0));
    c.shutdown();
    obs::set_sampling(0);
    assert!(count(&live.events(), Stage::Reply) >= 2, "replies recorded across the poison");
    assert!(Json::parse(&format!("{}", live.chrome_json())).is_ok());
}
