//! Format-oracle conversion suite: exhaustive bit-pattern sweeps for
//! every Tensor Core input format's scalar conversion oracle, plus the
//! plan-vs-oracle bitwise contract for the format precisions at every
//! worker count and pool mode.  The f16 sweep is the template: each
//! format's widen → round composition must be the identity on every
//! storage pattern (NaNs quieten canonically), so pack-time rounding is
//! idempotent and the emulated MAC consumes exact grid points.

use tensoremu::formats::{
    bf16_quantize, bf16_to_f32, f32_to_bf16, f32_to_fp8, f32_to_fp8e5m2, f32_to_int8, f32_to_tf32,
    fp8_quantize, fp8_to_f32, fp8e5m2_quantize, fp8e5m2_to_f32, int8_quantize, int8_to_f32,
    tf32_quantize, tf32_to_f32, Bf16, Fp8E4M3, Fp8E5M2, Int8, Scale, TcFormat, Tf32, FP8E5M2_MAX,
    FP8_MAX, INT8_QMAX, TF32_MAX,
};
use tensoremu::gemm::engine::{self, PoolMode};
use tensoremu::gemm::plan::{GemmDesc, Precision};
use tensoremu::gemm::{
    bf16_gemm_scalar, fp8_gemm_scalar, fp8e5m2_gemm_scalar, int8_gemm_scalar, tf32_gemm_scalar,
    Matrix,
};
use tensoremu::halfprec::{f16_to_f32, f32_to_f16, Half, F16, F16_MIN_POSITIVE_NORMAL};
use tensoremu::workload::{uniform_matrix, Rng};

const THREADS: &[usize] = &[1, 2, 8];

/// Serializes the tests that flip the process-global pool mode (same
/// rationale as tests/engine.rs — the mode is per-process state).
static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock_mode() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Satellite: exhaustive conversion sweeps.

#[test]
fn f16_exhaustive_all_65536_bit_patterns() {
    // every binary16 storage pattern: widen must classify correctly and
    // round(widen(p)) must return p exactly (NaNs quieten to the
    // canonical sign | 0x7E00 payload)
    for p in 0..=u16::MAX {
        let h = Half(p);
        let x = f16_to_f32(h);
        let r = f32_to_f16(x);
        let sign = p & 0x8000;
        let exp = p & 0x7C00;
        let sig = p & 0x03FF;
        if exp == 0x7C00 && sig != 0 {
            // NaN: stays NaN with the sign, payload canonicalized
            assert!(x.is_nan(), "{p:#06x} widened to {x}");
            assert_eq!(x.is_sign_negative(), sign != 0, "{p:#06x} NaN sign");
            assert_eq!(r, Half(sign | 0x7E00), "{p:#06x} NaN round-trip");
        } else {
            // finite and infinite patterns round-trip bit-exactly
            assert_eq!(r, h, "{p:#06x} round-trip");
            assert_eq!(x.is_infinite(), exp == 0x7C00, "{p:#06x} class");
            assert_eq!(x.is_sign_negative(), sign != 0, "{p:#06x} sign (x={x})");
            if exp == 0 && sig != 0 {
                // subnormals widen below the smallest normal, never to 0
                assert!(x != 0.0 && x.abs() < F16_MIN_POSITIVE_NORMAL, "{p:#06x} subnormal");
            }
            if exp == 0 && sig == 0 {
                assert_eq!(x.to_bits(), u32::from(sign) << 16, "{p:#06x} signed zero");
            }
            // the trait instance is the same oracle
            assert_eq!(F16.round_from_f32(x), h, "{p:#06x} trait");
            assert_eq!(F16.widen_to_f32(h).to_bits(), x.to_bits(), "{p:#06x} trait widen");
        }
    }
}

#[test]
fn bf16_exhaustive_all_65536_bit_patterns() {
    // bf16 is the top half of an f32: widening must be exactly the
    // 16-bit shift, and round(widen(p)) must return p (NaNs gain the
    // quiet bit, nothing else moves)
    for p in 0..=u16::MAX {
        let x = bf16_to_f32(p);
        assert_eq!(x.to_bits(), u32::from(p) << 16, "{p:#06x} widen is the shift");
        let r = f32_to_bf16(x);
        let exp = p & 0x7F80;
        let sig = p & 0x007F;
        if exp == 0x7F80 && sig != 0 {
            assert!(x.is_nan(), "{p:#06x}");
            assert_eq!(r, p | 0x0040, "{p:#06x} NaN quietens in place");
        } else {
            assert_eq!(r, p, "{p:#06x} round-trip");
        }
        assert_eq!(Bf16.round_from_f32(x), r, "{p:#06x} trait");
    }
}

#[test]
fn fp8_exhaustive_all_256_bit_patterns() {
    // all 256 E4M3 patterns round-trip exactly — including both NaN
    // patterns (sign-preserving) and both signed zeros
    for p in 0..=u8::MAX {
        let x = fp8_to_f32(p);
        let r = f32_to_fp8(x);
        assert_eq!(r, p, "{p:#04x} round-trip");
        if p & 0x7F == 0x7F {
            assert!(x.is_nan(), "{p:#04x}");
            assert_eq!(x.is_sign_negative(), p & 0x80 != 0, "{p:#04x} NaN sign");
        } else {
            assert!(x.is_finite(), "{p:#04x}: E4M3 has no infinities");
            assert!(x.abs() <= FP8_MAX, "{p:#04x} within ±448");
        }
        if p & 0x7F == 0 {
            assert_eq!(x.to_bits(), u32::from(p) << 24, "{p:#04x} signed zero");
        }
        assert_eq!(Fp8E4M3.round_from_f32(x), r, "{p:#04x} trait");
    }
}

#[test]
fn fp8e5m2_exhaustive_all_256_bit_patterns() {
    // all 256 E5M2 patterns round-trip exactly — unlike E4M3 this
    // format has real ±∞ (0x7C/0xFC) and three NaN significands per
    // sign, which quieten to the canonical sign | 0x7E pattern
    for p in 0..=u8::MAX {
        let x = fp8e5m2_to_f32(p);
        let r = f32_to_fp8e5m2(x);
        let sign = p & 0x80;
        let exp = p & 0x7C;
        let sig = p & 0x03;
        if exp == 0x7C && sig != 0 {
            assert!(x.is_nan(), "{p:#04x} widened to {x}");
            assert_eq!(x.is_sign_negative(), sign != 0, "{p:#04x} NaN sign");
            assert_eq!(r, sign | 0x7E, "{p:#04x} NaN canonicalizes");
        } else {
            assert_eq!(r, p, "{p:#04x} round-trip");
            assert_eq!(x.is_infinite(), exp == 0x7C, "{p:#04x} class");
            if exp != 0x7C {
                assert!(x.abs() <= FP8E5M2_MAX, "{p:#04x} within ±57344");
            }
        }
        if p & 0x7F == 0 {
            assert_eq!(x.to_bits(), u32::from(p) << 24, "{p:#04x} signed zero");
        }
        if exp == 0 && sig != 0 {
            // subnormals sit on the 2^-16 grid below the 2^-14 normal floor
            assert_eq!(x, f32::from(sig) * if sign != 0 { -1.0 } else { 1.0 } / 65_536.0);
        }
        assert_eq!(Fp8E5M2.round_from_f32(x), r, "{p:#04x} trait");
    }
}

#[test]
fn tf32_quantization_is_idempotent_with_canonical_specials() {
    // tf32 has 2^32 storage patterns, so sweep a dense random sample
    // plus every special instead: quantize must be idempotent, clear
    // the low 13 bits, and canonicalize NaN
    let mut rng = Rng::new(77);
    for _ in 0..100_000 {
        let x = f32::from_bits(rng.next_u64() as u32);
        if x.is_nan() {
            continue; // covered below
        }
        let q = tf32_quantize(x);
        assert_eq!(tf32_quantize(q).to_bits(), q.to_bits(), "{x} idempotent");
        if q.is_finite() {
            assert_eq!(q.to_bits() & 0x1FFF, 0, "{x} low bits cleared");
        }
    }
    assert_eq!(f32_to_tf32(f32::NAN), 0x7FC0_0000);
    assert_eq!(f32_to_tf32(-f32::NAN), 0xFFC0_0000);
    assert_eq!(tf32_quantize(f32::INFINITY), f32::INFINITY);
    assert_eq!(tf32_quantize(f32::NEG_INFINITY), f32::NEG_INFINITY);
    assert_eq!(tf32_quantize(TF32_MAX), TF32_MAX);
    assert_eq!(tf32_quantize(f32::MAX), f32::INFINITY, "overflow carries to inf");
    assert_eq!(tf32_quantize(-0.0).to_bits(), (-0.0f32).to_bits());
    assert_eq!(Tf32.round_from_f32(1.5), f32_to_tf32(1.5));
    assert_eq!(tf32_to_f32(f32_to_tf32(1.5)), 1.5);
}

#[test]
fn int8_exhaustive_grid_roundtrip_and_saturation() {
    // every representable grid point round-trips at several scales; the
    // quantizer saturates (never wraps, never emits -128) and flushes
    // NaN to zero
    for scale in [1.0f32 / 127.0, 0.25, 1.0, 3.5] {
        for q in -INT8_QMAX..=INT8_QMAX {
            let q = q as i8;
            let x = int8_to_f32(q, scale);
            assert_eq!(f32_to_int8(x, scale), q, "q={q} scale={scale}");
            assert_eq!(int8_quantize(x, scale), x, "q={q} scale={scale} idempotent");
        }
        assert_eq!(f32_to_int8(1e9, scale), 127, "scale={scale} saturates up");
        assert_eq!(f32_to_int8(-1e9, scale), -127, "scale={scale} saturates down");
        assert_eq!(f32_to_int8(f32::INFINITY, scale), 127);
        assert_eq!(f32_to_int8(f32::NEG_INFINITY, scale), -127);
        assert_eq!(f32_to_int8(f32::NAN, scale), 0, "NaN flushes to zero");
    }
    let fmt = Int8 { scale: Scale::new(0.5) };
    assert_eq!(fmt.round_from_f32(1.2), 2);
    assert_eq!(fmt.widen_to_f32(2), 1.0);
}

// ---------------------------------------------------------------------------
// Plan-vs-oracle: the format precisions join the bitwise contract.

type Oracle = fn(&Matrix, &Matrix) -> Matrix;

fn format_cases() -> Vec<(Precision, Oracle)> {
    fn bf16(a: &Matrix, b: &Matrix) -> Matrix {
        bf16_gemm_scalar(a, b, None, 1.0, 0.0)
    }
    fn tf32(a: &Matrix, b: &Matrix) -> Matrix {
        tf32_gemm_scalar(a, b, None, 1.0, 0.0)
    }
    fn fp8(a: &Matrix, b: &Matrix) -> Matrix {
        fp8_gemm_scalar(a, b, None, 1.0, 0.0)
    }
    fn fp8e5m2(a: &Matrix, b: &Matrix) -> Matrix {
        fp8e5m2_gemm_scalar(a, b, None, 1.0, 0.0)
    }
    fn int8_default(a: &Matrix, b: &Matrix) -> Matrix {
        int8_gemm_scalar(a, b, None, 1.0, 0.0, Scale::default().get())
    }
    fn int8_quarter(a: &Matrix, b: &Matrix) -> Matrix {
        int8_gemm_scalar(a, b, None, 1.0, 0.0, 0.25)
    }
    vec![
        (Precision::Bf16, bf16 as Oracle),
        (Precision::Tf32, tf32),
        (Precision::Fp8E4M3, fp8),
        (Precision::Fp8E5M2, fp8e5m2),
        (Precision::Int8 { scale: Scale::default() }, int8_default),
        (Precision::Int8 { scale: Scale::new(0.25) }, int8_quarter),
    ]
}

#[test]
fn format_plans_equal_scalar_oracles_for_every_thread_count_and_pool_mode() {
    // the acceptance sweep: {format precision} x {1,2,8} threads x
    // {scoped, persistent} pool, plan bits == oracle bits
    let _g = lock_mode();
    let ambient = engine::pool_mode();
    let mut rng = Rng::new(130);
    let a = uniform_matrix(&mut rng, 34, 29, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 29, 27, -1.0, 1.0);
    for (prec, oracle) in format_cases() {
        let want = oracle(&a, &b);
        for mode in [PoolMode::Scoped, PoolMode::Persistent] {
            engine::set_pool_mode(mode);
            for &t in THREADS {
                let plan = GemmDesc::new(34, 29, 27)
                    .precision(prec)
                    .threads(t)
                    .pool_hint(mode)
                    .plan(&a, &b)
                    .unwrap();
                assert_eq!(plan.execute().unwrap(), want, "{prec:?} {mode:?} t={t}");
            }
        }
    }
    engine::set_pool_mode(ambient);
}

#[test]
fn batched_format_plans_equal_per_entry_oracles_across_threads_and_pools() {
    // the engine lane's call shape for format buckets: batched format
    // plans are per-entry bitwise equal to the scalar oracles at every
    // worker count and pool mode
    let _g = lock_mode();
    let ambient = engine::pool_mode();
    let mut rng = Rng::new(131);
    let shapes = [(16usize, 16usize, 16usize), (5, 7, 3), (33, 20, 12), (1, 1, 1)];
    let a: Vec<Matrix> =
        shapes.iter().map(|&(m, k, _)| uniform_matrix(&mut rng, m, k, -1.0, 1.0)).collect();
    let b: Vec<Matrix> =
        shapes.iter().map(|&(_, k, n)| uniform_matrix(&mut rng, k, n, -1.0, 1.0)).collect();
    for (prec, oracle) in format_cases() {
        let want: Vec<Matrix> = a.iter().zip(&b).map(|(x, y)| oracle(x, y)).collect();
        for pm in [PoolMode::Scoped, PoolMode::Persistent] {
            engine::set_pool_mode(pm);
            for &t in THREADS {
                let plan = GemmDesc::any_shape().precision(prec).threads(t).build().unwrap();
                assert_eq!(plan.execute_batched(&a, &b).unwrap(), want, "{prec:?} {pm:?} t={t}");
            }
        }
    }
    engine::set_pool_mode(ambient);
}

#[test]
fn quantize_helpers_and_trait_instances_agree_on_random_inputs() {
    // one contract, two spellings: the free quantize helpers and the
    // TcFormat instances must agree bit for bit on arbitrary inputs
    let mut rng = Rng::new(132);
    let i8f = Int8 { scale: Scale::new(0.03) };
    for _ in 0..10_000 {
        let x = f32::from_bits(rng.next_u64() as u32);
        if x.is_nan() {
            continue;
        }
        assert_eq!(Bf16.quantize(x).to_bits(), bf16_quantize(x).to_bits(), "bf16 {x}");
        assert_eq!(Tf32.quantize(x).to_bits(), tf32_quantize(x).to_bits(), "tf32 {x}");
        assert_eq!(Fp8E4M3.quantize(x).to_bits(), fp8_quantize(x).to_bits(), "fp8 {x}");
        assert_eq!(
            Fp8E5M2.quantize(x).to_bits(),
            fp8e5m2_quantize(x).to_bits(),
            "fp8e5m2 {x}"
        );
        assert_eq!(i8f.quantize(x).to_bits(), int8_quantize(x, 0.03).to_bits(), "int8 {x}");
        assert_eq!(
            F16.quantize(x).to_bits(),
            f16_to_f32(f32_to_f16(x)).to_bits(),
            "f16 {x}"
        );
    }
}
