//! Bench target for Fig. 9: the cost/precision scatter — measured errors
//! (PJRT error probes) x modeled device times, plus the *measured* cost
//! factors of the refinement pipeline on real artifacts (one GEMM vs the
//! 2-GEMM and 4-GEMM refined variants at the same size).
//!
//! Run: `cargo bench --bench fig9_tradeoff`  (needs `make artifacts`)

use tensoremu::figures::fig9;
use tensoremu::runtime::{Engine, TensorData};
use tensoremu::sim::VoltaConfig;
use tensoremu::util::bench::bench_config;
use tensoremu::workload::{uniform_matrix, Rng};

fn main() {
    let mut engine = Engine::discover().expect("run `make artifacts` first");
    let cfg = VoltaConfig::tesla_v100_pdc();
    let trials = std::env::var("FIG9_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let f = fig9::compute(&mut engine, &cfg, trials, 42).unwrap();
    println!("{}", fig9::render(&f));

    // measured cost factors of the refinement pipeline on real artifacts
    let n = 512;
    let mut rng = Rng::new(5);
    let a = TensorData::from_matrix(&uniform_matrix(&mut rng, n, n, -1.0, 1.0));
    let b = TensorData::from_matrix(&uniform_matrix(&mut rng, n, n, -1.0, 1.0));
    let mut means = Vec::new();
    for op in ["mixed", "refine_a", "refine_ab"] {
        let name = engine.manifest().gemm(op, n).unwrap().name.clone();
        let r = bench_config(&format!("pjrt/{op}_n{n}"), 8, 50, 30_000, || {
            std::hint::black_box(engine.run(&name, &[a.clone(), b.clone()]).unwrap());
        });
        println!("{}", r.report());
        means.push((op, r.mean().as_secs_f64()));
    }
    let base = means[0].1;
    println!("\nmeasured cost factors vs one mixed GEMM @ N={n} (paper: 2.25x / ~5x):");
    for (op, m) in &means {
        println!("  {op:<10} {:.2}x", m / base);
    }
}
