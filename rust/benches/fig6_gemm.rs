//! Bench target for Fig. 6: regenerates the GEMM Tflops/s-vs-N table
//! from the Volta model and, as the host-side measured counterpart,
//! times the Rust emulation backends (wmma-tiled vs cutlass-tiled vs
//! cpu-blocked sgemm) on a small N so the *relative* shape of the
//! interface survey is also exercised with real code.
//!
//! Run: `cargo bench --bench fig6_gemm`

use tensoremu::figures::fig6;
use tensoremu::gemm::sgemm_blocked;
use tensoremu::interfaces::{wmma_tiled_gemm, CutlassGemm, TilePolicy};
use tensoremu::sim::VoltaConfig;
use tensoremu::util::bench::bench;
use tensoremu::workload::{uniform_matrix, Rng};

fn main() {
    // device-model regeneration (the actual Fig. 6 series)
    let cfg = VoltaConfig::tesla_v100_pdc();
    println!("{}", fig6::render(&fig6::compute(&cfg)));

    // host-side emulation micro-bench (structure only; absolute numbers
    // are CPU emulation, not device performance)
    let n = 128;
    let mut rng = Rng::new(1);
    let a = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, n, n, -1.0, 1.0);
    let flops = 2.0 * (n as f64).powi(3);

    let r = bench("emu/sgemm_blocked_128", 20, || {
        std::hint::black_box(sgemm_blocked(&a, &b, None, 1.0, 0.0));
    });
    println!("{}  ({:.2} Gflop/s)", r.report(), r.harmonic_mean_rate(flops) / 1e9);

    let r = bench("emu/wmma_tiled_128", 10, || {
        std::hint::black_box(wmma_tiled_gemm(&a, &b));
    });
    println!("{}  ({:.2} Gflop/s)", r.report(), r.harmonic_mean_rate(flops) / 1e9);

    let cutlass = CutlassGemm::new(TilePolicy::DEFAULT);
    let r = bench("emu/cutlass_tiled_128", 10, || {
        std::hint::black_box(cutlass.run(&a, &b));
    });
    println!("{}  ({:.2} Gflop/s)", r.report(), r.harmonic_mean_rate(flops) / 1e9);
}
