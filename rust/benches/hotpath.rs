//! Hot-path benchmarks for the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Part 1 — **engine vs scalar**: the packed multithreaded GEMM engine
//! (persistent pool + kc/mc cache blocking + 8x8 microkernel) against the
//! serial scalar oracles it replaced, on the two shapes the acceptance
//! bar names (512^3 mixed GEMM, 1024-tile batched 16x16), plus the hgemm
//! repack-reuse path and a batched refined comparison (a loop of
//! per-entry `refine_gemm` singles vs one batched refined plan driving
//! the Eq. 3 chains over the pool — the refined engine-lane shape),
//! plus a strided-batched comparison (zero-copy `StridedBatch` views vs
//! the per-call `Vec<Matrix>` gather the pre-view API forced — the
//! `cublasGemmStridedBatched` axis of ISSUE 5), plus the 2:4 sparse
//! lane against the dense engine over the same pruned operand (bitwise
//! equal outputs; the sparse microkernel skips half of A's FLOPs).
//!
//! Part 2 — **persistent vs scoped pool** on repeated small GEMMs: the
//! per-call latency axis (a scoped fork-join pays thread spawns on every
//! call; the warm persistent pool only a latch round-trip).
//!
//! Part 3 — **plan reuse**: a cached [`GemmDesc`]-built plan (operands
//! packed once) against the one-shot wrappers (re-pack per call), on a
//! repeated small GEMM and on a refine chain sharing packed A across
//! swapped B operands (`set_b`) — the reuse the plan layer exists for.
//!
//! Part 4 — **L3 serving components** (router / batcher / tensor
//! conversion / PJRT execution), which require `make artifacts`; skipped
//! gracefully when the artifacts are absent.
//!
//! Requires nothing but the crate; writes a machine-readable baseline to
//! `BENCH_hotpath.json` — schema records `threads`, pool mode, blocking
//! params (`MR/NR/KC/MC`) and the `simd` feature state alongside the
//! numbers, so baselines stay attributable.  Env knobs: `BENCH_OUT`
//! overrides the output path, `BENCH_SMOKE=1` shrinks shapes/iterations
//! to CI-smoke size and redirects output to `BENCH_hotpath.smoke.json`
//! (smoke shapes are a sanity signal, not the acceptance measurement).
//!
//! Run: `cargo bench --bench hotpath`

use std::time::Duration;

use tensoremu::coordinator::{Batcher, BatcherConfig, GemmRequest, PrecisionPolicy, Router};
use tensoremu::formats::Scale;
use tensoremu::gemm::engine::{self, PackedHalfA, PackedHalfB, PoolMode};
use tensoremu::gemm::{
    batched_mixed_gemm, batched_mixed_gemm_scalar, bf16_gemm_scalar, fp8_gemm_scalar,
    hgemm_scalar, int8_gemm_scalar, mixed_gemm, mixed_gemm_scalar, tf32_gemm_scalar, GemmDesc,
    MatLayout, Matrix, Precision, Sparsity, StridedBatch,
};
use tensoremu::precision::{batched_refine_gemm, refine_gemm, RefineMode};
use tensoremu::runtime::{Engine, Manifest, TensorData};
use tensoremu::util::bench::{bench, bench_config, BenchResult};
use tensoremu::workload::{uniform_batch, uniform_matrix, Rng};

struct Comparison {
    name: &'static str,
    scalar: BenchResult,
    engine: BenchResult,
}

impl Comparison {
    fn speedup(&self) -> f64 {
        self.scalar.mean().as_secs_f64() / self.engine.mean().as_secs_f64().max(1e-12)
    }
}

struct PoolComparison {
    name: String,
    scoped: BenchResult,
    persistent: BenchResult,
}

impl PoolComparison {
    fn speedup(&self) -> f64 {
        self.scoped.mean().as_secs_f64() / self.persistent.mean().as_secs_f64().max(1e-12)
    }
}

/// One-shot wrapper (re-pack per call) vs cached plan (packed once).
struct PlanComparison {
    name: String,
    oneshot: BenchResult,
    cached: BenchResult,
}

impl PlanComparison {
    fn speedup(&self) -> f64 {
        self.oneshot.mean().as_secs_f64() / self.cached.mean().as_secs_f64().max(1e-12)
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    if smoke {
        println!("BENCH_SMOKE: reduced shapes/iterations (CI smoke mode) — these are");
        println!("sanity numbers, NOT the mixed_512/batched_1024x16 acceptance shapes\n");
    }
    let mut rng = Rng::new(1);
    // the mode the engine-vs-scalar comparisons actually run under
    // (TENSOREMU_POOL-selectable) — recorded in the baseline, and
    // restored after the pool-comparison section flips modes
    let initial_mode = engine::pool_mode();
    let mut comparisons = Vec::new();

    // -- direct-path shape of Fig. 6 (512^3 mixed GEMM; 128^3 in smoke)
    let nm = if smoke { 128 } else { 512 };
    let mixed_name: &'static str = if smoke { "mixed_128" } else { "mixed_512" };
    let a = uniform_matrix(&mut rng, nm, nm, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, nm, nm, -1.0, 1.0);
    let scalar = bench_config("gemm/mixed_scalar", 3, 0, 30_000, || {
        std::hint::black_box(mixed_gemm_scalar(&a, &b, None, 1.0, 0.0));
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/mixed_engine", 30, 300, 10_000, || {
        std::hint::black_box(mixed_gemm(&a, &b, None, 1.0, 0.0));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: mixed_name, scalar, engine: fast });

    // -- batched 16x16 tiles: the Fig. 7 / coordinator batch shape
    let nbatch = if smoke { 128 } else { 1024 };
    let batch_name: &'static str = if smoke { "batched_128x16" } else { "batched_1024x16" };
    let ab = uniform_batch(&mut rng, nbatch, 16, -1.0, 1.0);
    let bb = uniform_batch(&mut rng, nbatch, 16, -1.0, 1.0);
    let scalar = bench_config("gemm/batched_scalar", 10, 0, 30_000, || {
        std::hint::black_box(batched_mixed_gemm_scalar(&ab, &bb));
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/batched_engine", 50, 300, 10_000, || {
        std::hint::black_box(batched_mixed_gemm(&ab, &bb));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: batch_name, scalar, engine: fast });

    // -- hgemm: per-call repacking vs pre-packed operand reuse
    let nh = if smoke { 96 } else { 256 };
    let hg_name: &'static str = if smoke { "hgemm_96_prepacked" } else { "hgemm_256_prepacked" };
    let a = uniform_matrix(&mut rng, nh, nh, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, nh, nh, -1.0, 1.0);
    let scalar = bench_config("gemm/hgemm_scalar", 3, 0, 30_000, || {
        std::hint::black_box(hgemm_scalar(&a, &b));
    });
    println!("{}", scalar.report());
    let pa = PackedHalfA::pack(&a);
    let pb = PackedHalfB::pack(&b);
    let fast = bench_config("gemm/hgemm_prepacked_engine", 20, 300, 10_000, || {
        std::hint::black_box(engine::hgemm_packed(&pa, &pb, 0));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: hg_name, scalar, engine: fast });

    // -- format zoo: each new format precision's engine path (pack-time
    //    quantization on the pool) vs its serial scalar oracle, one row
    //    per format in the baseline schema — additive rows, the existing
    //    schema keys are untouched
    let nf = if smoke { 64 } else { 256 };
    let fa = uniform_matrix(&mut rng, nf, nf, -1.0, 1.0);
    let fb = uniform_matrix(&mut rng, nf, nf, -1.0, 1.0);
    let scale = Scale::default();
    let fmt_cases: [(&'static str, &'static str, Precision); 4] = [
        ("bf16_256", "bf16_64", Precision::Bf16),
        ("tf32_256", "tf32_64", Precision::Tf32),
        ("fp8e4m3_256", "fp8e4m3_64", Precision::Fp8E4M3),
        ("int8_256", "int8_64", Precision::Int8 { scale }),
    ];
    for (full_name, smoke_name, prec) in fmt_cases {
        let name = if smoke { smoke_name } else { full_name };
        let scalar = bench_config(&format!("gemm/{name}_scalar"), 3, 0, 30_000, || {
            std::hint::black_box(match prec {
                Precision::Bf16 => bf16_gemm_scalar(&fa, &fb, None, 1.0, 0.0),
                Precision::Tf32 => tf32_gemm_scalar(&fa, &fb, None, 1.0, 0.0),
                Precision::Fp8E4M3 => fp8_gemm_scalar(&fa, &fb, None, 1.0, 0.0),
                Precision::Int8 { scale } => {
                    int8_gemm_scalar(&fa, &fb, None, 1.0, 0.0, scale.get())
                }
                other => unreachable!("format sweep only: {other:?}"),
            });
        });
        println!("{}", scalar.report());
        let plan = GemmDesc::square(nf).precision(prec).plan(&fa, &fb).unwrap();
        let fast = bench_config(&format!("gemm/{name}_engine"), 30, 300, 10_000, || {
            std::hint::black_box(plan.execute().unwrap());
        });
        println!("{}", fast.report());
        comparisons.push(Comparison { name, scalar, engine: fast });
    }

    // -- 2:4 sparse lane vs the dense engine over the same pruned
    //    operand: both plans produce bitwise-identical results (the
    //    sparse microkernel walks the metadata and skips the pruned
    //    half of A's FLOPs), so the row measures the pure lane
    //    speedup.  The "scalar" column here is the dense f32 plan
    //    over the materialized pruned A — additive row, existing
    //    schema keys untouched.
    let nsp = if smoke { 64 } else { 256 };
    let sp_name: &'static str = if smoke { "sparse24_64" } else { "sparse24_256" };
    let spa = uniform_matrix(&mut rng, nsp, nsp, -1.0, 1.0);
    let spb = uniform_matrix(&mut rng, nsp, nsp, -1.0, 1.0);
    let pruned = engine::sparse24_prune(&spa);
    let dense_plan =
        GemmDesc::square(nsp).precision(Precision::F32).plan(&pruned, &spb).unwrap();
    let scalar = bench_config("gemm/sparse24_dense_engine_pruned", 30, 300, 10_000, || {
        std::hint::black_box(dense_plan.execute().unwrap());
    });
    println!("{}", scalar.report());
    let sparse_plan = GemmDesc::square(nsp)
        .precision(Precision::F32)
        .sparsity(Sparsity::Sparse24)
        .plan(&spa, &spb)
        .unwrap();
    let fast = bench_config("gemm/sparse24_engine", 30, 300, 10_000, || {
        std::hint::black_box(sparse_plan.execute().unwrap());
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: sp_name, scalar, engine: fast });

    // -- batched refined chains (the §IV-B batched shape at §V
    //    precision): a loop of per-entry refine_gemm singles vs one
    //    batched refined plan distributing the Eq. 3 chains over the
    //    pool — the refined engine-lane shape
    let nrb = if smoke { 16 } else { 64 };
    let rb_name: &'static str =
        if smoke { "batched_refine_ab_16x32" } else { "batched_refine_ab_64x32" };
    let ra = uniform_batch(&mut rng, nrb, 32, -1.0, 1.0);
    let rbm = uniform_batch(&mut rng, nrb, 32, -1.0, 1.0);
    let scalar = bench_config("gemm/refine_ab_singles_loop", 10, 0, 30_000, || {
        for (x, y) in ra.iter().zip(&rbm) {
            std::hint::black_box(refine_gemm(x, y, RefineMode::RefineAB));
        }
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/refine_ab_batched_engine", 30, 300, 10_000, || {
        std::hint::black_box(batched_refine_gemm(&ra, &rbm, RefineMode::RefineAB));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: rb_name, scalar, engine: fast });

    // -- strided batched vs Vec<Matrix> batch (the layout/view API
    //    axis): both sides run the same cached any_shape plan over the
    //    same contiguous buffers, so the only difference is the gather —
    //    the owned path materializes a Vec<Matrix> per call (what the
    //    pre-view API forced), the strided path hands zero-copy
    //    StridedBatch views straight to the engine
    let nsv = if smoke { 16 } else { 64 };
    let sv_name: &'static str =
        if smoke { "strided_batched_vs_vec_16x32" } else { "strided_batched_vs_vec_64x32" };
    let edge = 32usize;
    let sva = uniform_batch(&mut rng, nsv, edge, -1.0, 1.0);
    let svb = uniform_batch(&mut rng, nsv, edge, -1.0, 1.0);
    let abuf: Vec<f32> = sva.iter().flat_map(|m| m.as_slice().iter().copied()).collect();
    let bbuf: Vec<f32> = svb.iter().flat_map(|m| m.as_slice().iter().copied()).collect();
    let lay = MatLayout::new(edge, edge);
    let entry = edge * edge;
    let splan = GemmDesc::any_shape().build().unwrap();
    let scalar = bench_config("gemm/batched_vec_gather", 30, 0, 30_000, || {
        let av: Vec<Matrix> = (0..nsv)
            .map(|i| Matrix::from_vec(edge, edge, abuf[i * entry..(i + 1) * entry].to_vec()))
            .collect();
        let bv: Vec<Matrix> = (0..nsv)
            .map(|i| Matrix::from_vec(edge, edge, bbuf[i * entry..(i + 1) * entry].to_vec()))
            .collect();
        std::hint::black_box(splan.execute_batched(&av, &bv).unwrap());
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/batched_strided_views", 30, 300, 10_000, || {
        let sa = StridedBatch::new(&abuf, lay, entry, nsv);
        let sb = StridedBatch::new(&bbuf, lay, entry, nsv);
        std::hint::black_box(splan.execute_strided_batched(&sa, &sb).unwrap());
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: sv_name, scalar, engine: fast });

    // -- persistent vs scoped pool: repeated small (<= 128^3) GEMMs,
    //    where per-call thread spawns dominate the scoped path
    let np = if smoke { 64 } else { 96 };
    let a = uniform_matrix(&mut rng, np, np, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, np, np, -1.0, 1.0);
    // explicit worker count: the latency comparison must not collapse to
    // the serial path via the auto cutoff
    let t = engine::default_threads().clamp(2, 8);
    engine::set_pool_mode(PoolMode::Scoped);
    let scoped = bench_config("pool/small_repeated_scoped", 200, 100, 5_000, || {
        std::hint::black_box(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t));
    });
    println!("{}", scoped.report());
    engine::set_pool_mode(PoolMode::Persistent);
    let persistent = bench_config("pool/small_repeated_persistent", 200, 100, 5_000, || {
        std::hint::black_box(engine::mixed_gemm(&a, &b, None, 1.0, 0.0, t));
    });
    println!("{}", persistent.report());
    engine::set_pool_mode(initial_mode);
    let pool_cmp = PoolComparison { name: format!("mixed_{np}^3_t{t}"), scoped, persistent };

    // -- plan reuse: one-shot wrapper (re-packs both operands per call)
    //    vs a cached GemmPlan (packed once at build, executed repeatedly)
    let npl = if smoke { 64 } else { 96 };
    let a = uniform_matrix(&mut rng, npl, npl, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, npl, npl, -1.0, 1.0);
    let oneshot = bench_config("plan/mixed_oneshot", 200, 100, 5_000, || {
        std::hint::black_box(mixed_gemm(&a, &b, None, 1.0, 0.0));
    });
    println!("{}", oneshot.report());
    let plan = GemmDesc::square(npl).precision(Precision::Mixed).plan(&a, &b).unwrap();
    let cached = bench_config("plan/mixed_cached_plan", 200, 100, 5_000, || {
        std::hint::black_box(plan.execute().unwrap());
    });
    println!("{}", cached.report());
    let plan_cmp = PlanComparison { name: format!("mixed_{npl}^3"), oneshot, cached };

    // -- refine chain with shared packed A: one-shot refine_gemm splits
    //    and packs A on every call; the cached plan swaps B (set_b) while
    //    A's two split panels stay warm
    let bs: Vec<Matrix> =
        (0..4).map(|_| uniform_matrix(&mut rng, npl, npl, -1.0, 1.0)).collect();
    let oneshot = bench_config("plan/refine_a_oneshot_x4", 50, 100, 5_000, || {
        for bi in &bs {
            std::hint::black_box(refine_gemm(&a, bi, RefineMode::RefineA));
        }
    });
    println!("{}", oneshot.report());
    let mut rplan = GemmDesc::square(npl)
        .precision(Precision::Refined(RefineMode::RefineA))
        .plan(&a, &bs[0])
        .unwrap();
    let cached = bench_config("plan/refine_a_cached_swap_b_x4", 50, 100, 5_000, || {
        for bi in &bs {
            rplan.set_b(bi).unwrap();
            std::hint::black_box(rplan.execute().unwrap());
        }
    });
    println!("{}", cached.report());
    let refine_cmp =
        PlanComparison { name: format!("refine_a_{npl}^3_shared_a_x4b"), oneshot, cached };

    println!();
    for c in &comparisons {
        println!(
            "speedup {:<24} {:>7.2}x  (engine threads: {})",
            c.name,
            c.speedup(),
            engine::default_threads()
        );
    }
    println!(
        "speedup {:<24} {:>7.2}x  (persistent pool vs scoped spawns)",
        pool_cmp.name,
        pool_cmp.speedup()
    );
    for pc in [&plan_cmp, &refine_cmp] {
        println!(
            "speedup {:<24} {:>7.2}x  (cached plan vs one-shot wrapper)",
            pc.name,
            pc.speedup()
        );
    }
    println!(
        "targets (ISSUE 2): >= 4x on mixed_512 and batched_1024x16 vs the scalar seed \
         kernels; persistent > scoped on repeated small GEMMs; \
         (ISSUE 3) cached plans > one-shot wrappers on repeated/refined GEMMs; \
         (ISSUE 4) batched refined plan > per-entry refine_gemm loop; \
         (ISSUE 5) zero-copy strided views >= per-call Vec<Matrix> gather; \
         (ISSUE 9) sparse24 engine >= 1.0x the dense engine on the same pruned operand"
    );

    write_baseline(&comparisons, &pool_cmp, &plan_cmp, &refine_cmp, initial_mode, smoke);

    // -- L3 serving components: need the AOT artifacts
    match Manifest::discover() {
        Ok(manifest) => l3_benches(manifest, &mut rng),
        Err(e) => println!("\nskipping L3/PJRT sections (artifacts not built): {e:#}"),
    }
}

fn write_baseline(
    comparisons: &[Comparison],
    pool_cmp: &PoolComparison,
    plan_cmp: &PlanComparison,
    refine_cmp: &PlanComparison,
    mode_ran: PoolMode,
    smoke: bool,
) {
    // default to the repo root, not the bench CWD; smoke runs get their
    // own file so they can never clobber the committed full-shape
    // baseline with non-comparable reduced-shape numbers
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        if smoke {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.smoke.json").to_string()
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json").to_string()
        }
    });
    let mut rows = Vec::new();
    for c in comparisons {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"scalar_ms\": {:.3}, \"engine_ms\": {:.3}, \"speedup\": {:.2}}}",
            c.name,
            c.scalar.mean().as_secs_f64() * 1e3,
            c.engine.mean().as_secs_f64() * 1e3,
            c.speedup()
        ));
    }
    let (mr, nr, kc, mc) = engine::blocking_params();
    let plan_json = |pc: &PlanComparison| {
        format!(
            "{{\"name\": \"{}\", \"oneshot_ms\": {:.3}, \"cached_ms\": {:.3}, \"speedup\": {:.2}}}",
            pc.name,
            pc.oneshot.mean().as_secs_f64() * 1e3,
            pc.cached.mean().as_secs_f64() * 1e3,
            pc.speedup()
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"mode\": \"{mode}\",\n  \"threads\": {threads},\n  \
         \"pool\": \"{pool}\",\n  \
         \"blocking\": {{\"mr\": {mr}, \"nr\": {nr}, \"kc\": {kc}, \"mc\": {mc}}},\n  \
         \"simd\": {simd},\n  \"results\": [\n{rows}\n  ],\n  \
         \"pool_comparison\": {{\"name\": \"{pname}\", \"scoped_ms\": {sms:.3}, \
         \"persistent_ms\": {pms:.3}, \"speedup\": {pspeed:.2}}},\n  \
         \"plan_cache\": {{\"repeated_gemm\": {plan_repeat}, \"refine_shared_a\": {plan_refine}}}\n}}\n",
        mode = if smoke { "smoke" } else { "full" },
        threads = engine::default_threads(),
        pool = match mode_ran {
            PoolMode::Persistent => "persistent",
            PoolMode::Scoped => "scoped",
        },
        simd = cfg!(feature = "simd"),
        rows = rows.join(",\n"),
        pname = pool_cmp.name,
        sms = pool_cmp.scoped.mean().as_secs_f64() * 1e3,
        pms = pool_cmp.persistent.mean().as_secs_f64() * 1e3,
        pspeed = pool_cmp.speedup(),
        plan_repeat = plan_json(plan_cmp),
        plan_refine = plan_json(refine_cmp),
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn l3_benches(manifest: Manifest, rng: &mut Rng) {
    // -- router: requests/second it can classify
    let router = Router::new(manifest, 16, PrecisionPolicy::default());
    let reqs: Vec<GemmRequest> = (0..256)
        .map(|i| {
            let n = [16usize, 64, 256][i % 3];
            GemmRequest::new(i as u64, uniform_matrix(rng, n, n, -1.0, 1.0),
                             uniform_matrix(rng, n, n, -1.0, 1.0))
        })
        .collect();
    let r = bench("l3/router_route_256req", 200, || {
        for req in &reqs {
            std::hint::black_box(router.route(req));
        }
    });
    println!("{}  ({:.0} routes/s)", r.report(), 256.0 / r.mean().as_secs_f64());

    // -- batcher: enqueue + flush cycle
    let r = bench("l3/batcher_push_flush_1024", 100, || {
        let mut b = Batcher::new(
            16,
            BatcherConfig {
                max_batch: 1024,
                max_wait: Duration::from_secs(1),
                ..Default::default()
            },
        );
        for i in 0..1024u64 {
            b.push(GemmRequest::new(i, Matrix::eye(16), Matrix::eye(16)));
        }
        std::hint::black_box(b.flush(|n| n).unwrap());
    });
    println!("{}  ({:.0} req/s through the batcher)", r.report(),
             1024.0 / r.mean().as_secs_f64());

    // -- batcher: bucketed flush of heterogeneous square shapes (the
    //    engine lane pays zero padding work)
    let r = bench("l3/batcher_flush_buckets_3x256", 100, || {
        let mut b = Batcher::new(
            16,
            BatcherConfig {
                max_batch: 1024,
                max_wait: Duration::from_secs(1),
                ..Default::default()
            },
        );
        for i in 0..768u64 {
            let n = [8usize, 16, 32][(i % 3) as usize];
            b.push(GemmRequest::new(i, Matrix::eye(n), Matrix::eye(n)));
        }
        std::hint::black_box(b.flush_buckets());
    });
    println!("{}  ({:.0} req/s bucketed)", r.report(), 768.0 / r.mean().as_secs_f64());

    // -- tensor conversion: Matrix -> TensorData -> literal-ready bytes
    let ms: Vec<Matrix> = (0..256).map(|_| uniform_matrix(rng, 16, 16, -1.0, 1.0)).collect();
    let r = bench("l3/tensor_from_batch_256x16x16", 500, || {
        std::hint::black_box(TensorData::from_batch(&ms).unwrap());
    });
    println!("{}", r.report());

    // -- PJRT execution reference point (what the overhead competes with)
    let mut engine = Engine::discover().unwrap();
    let a = TensorData::from_batch(&ms).unwrap();
    let name = engine.manifest().batched_at_least(256, 16).unwrap().name.clone();
    let r = bench_config("pjrt/batched_b256_reference", 20, 100, 20_000, || {
        std::hint::black_box(engine.run(&name, &[a.clone(), a.clone()]).unwrap());
    });
    println!("{}", r.report());

    println!("\ntarget (DESIGN.md §Perf): router+batcher+conversion must stay well under");
    println!("the PJRT execution time above — L3 is not allowed to be the bottleneck.");
}
