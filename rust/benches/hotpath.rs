//! Hot-path benchmarks for the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Part 1 — **engine vs scalar**: the packed multithreaded GEMM engine
//! against the serial scalar oracles it replaced, on the two shapes the
//! acceptance bar names (512^3 mixed GEMM, 1024-tile batched 16x16), plus
//! the hgemm repack-reuse path.  Requires nothing but the crate; writes a
//! machine-readable baseline to `BENCH_hotpath.json` (override the path
//! with `BENCH_OUT`) so future PRs have a perf trajectory.
//!
//! Part 2 — **L3 serving components** (router / batcher / tensor
//! conversion / PJRT execution), which require `make artifacts`; skipped
//! gracefully when the artifacts are absent.
//!
//! Run: `cargo bench --bench hotpath`

use std::time::Duration;

use tensoremu::coordinator::{Batcher, BatcherConfig, GemmRequest, PrecisionPolicy, Router};
use tensoremu::gemm::engine::{self, PackedHalfA, PackedHalfB};
use tensoremu::gemm::{
    batched_mixed_gemm, batched_mixed_gemm_scalar, hgemm_scalar, mixed_gemm, mixed_gemm_scalar,
    Matrix,
};
use tensoremu::runtime::{Engine, Manifest, TensorData};
use tensoremu::util::bench::{bench, bench_config, BenchResult};
use tensoremu::workload::{uniform_batch, uniform_matrix, Rng};

struct Comparison {
    name: &'static str,
    scalar: BenchResult,
    engine: BenchResult,
}

impl Comparison {
    fn speedup(&self) -> f64 {
        self.scalar.mean().as_secs_f64() / self.engine.mean().as_secs_f64().max(1e-12)
    }
}

fn main() {
    let mut rng = Rng::new(1);
    let mut comparisons = Vec::new();

    // -- 512^3 mixed GEMM: the direct-path shape of Fig. 6
    let a = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 512, 512, -1.0, 1.0);
    let scalar = bench_config("gemm/mixed_512_scalar", 3, 0, 30_000, || {
        std::hint::black_box(mixed_gemm_scalar(&a, &b, None, 1.0, 0.0));
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/mixed_512_engine", 30, 300, 10_000, || {
        std::hint::black_box(mixed_gemm(&a, &b, None, 1.0, 0.0));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: "mixed_512", scalar, engine: fast });

    // -- 1024-tile batched 16x16: the Fig. 7 / coordinator batch shape
    let ab = uniform_batch(&mut rng, 1024, 16, -1.0, 1.0);
    let bb = uniform_batch(&mut rng, 1024, 16, -1.0, 1.0);
    let scalar = bench_config("gemm/batched_1024x16_scalar", 10, 0, 30_000, || {
        std::hint::black_box(batched_mixed_gemm_scalar(&ab, &bb));
    });
    println!("{}", scalar.report());
    let fast = bench_config("gemm/batched_1024x16_engine", 50, 300, 10_000, || {
        std::hint::black_box(batched_mixed_gemm(&ab, &bb));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: "batched_1024x16", scalar, engine: fast });

    // -- hgemm 256^2: per-call repacking vs pre-packed operand reuse
    let a = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let b = uniform_matrix(&mut rng, 256, 256, -1.0, 1.0);
    let scalar = bench_config("gemm/hgemm_256_scalar", 3, 0, 30_000, || {
        std::hint::black_box(hgemm_scalar(&a, &b));
    });
    println!("{}", scalar.report());
    let pa = PackedHalfA::pack(&a);
    let pb = PackedHalfB::pack(&b);
    let fast = bench_config("gemm/hgemm_256_prepacked_engine", 20, 300, 10_000, || {
        std::hint::black_box(engine::hgemm_packed(&pa, &pb, 0));
    });
    println!("{}", fast.report());
    comparisons.push(Comparison { name: "hgemm_256_prepacked", scalar, engine: fast });

    println!();
    for c in &comparisons {
        println!("speedup {:<24} {:>7.2}x  (engine threads: {})", c.name, c.speedup(),
                 engine::default_threads());
    }
    println!("target (ISSUE 1): >= 4x on mixed_512 and batched_1024x16 vs the scalar seed kernels");

    write_baseline(&comparisons);

    // -- L3 serving components: need the AOT artifacts
    match Manifest::discover() {
        Ok(manifest) => l3_benches(manifest, &mut rng),
        Err(e) => println!("\nskipping L3/PJRT sections (artifacts not built): {e:#}"),
    }
}

fn write_baseline(comparisons: &[Comparison]) {
    // default to the committed repo-root baseline, not the bench CWD
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json").to_string()
    });
    let mut rows = Vec::new();
    for c in comparisons {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"scalar_ms\": {:.3}, \"engine_ms\": {:.3}, \"speedup\": {:.2}}}",
            c.name,
            c.scalar.mean().as_secs_f64() * 1e3,
            c.engine.mean().as_secs_f64() * 1e3,
            c.speedup()
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"threads\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        engine::default_threads(),
        rows.join(",\n")
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn l3_benches(manifest: Manifest, rng: &mut Rng) {
    // -- router: requests/second it can classify
    let router = Router::new(manifest, 16, PrecisionPolicy::default());
    let reqs: Vec<GemmRequest> = (0..256)
        .map(|i| {
            let n = [16usize, 64, 256][i % 3];
            GemmRequest::new(i as u64, uniform_matrix(rng, n, n, -1.0, 1.0),
                             uniform_matrix(rng, n, n, -1.0, 1.0))
        })
        .collect();
    let r = bench("l3/router_route_256req", 200, || {
        for req in &reqs {
            std::hint::black_box(router.route(req));
        }
    });
    println!("{}  ({:.0} routes/s)", r.report(), 256.0 / r.mean().as_secs_f64());

    // -- batcher: enqueue + flush cycle
    let r = bench("l3/batcher_push_flush_1024", 100, || {
        let mut b = Batcher::new(
            16,
            BatcherConfig { max_batch: 1024, max_wait: Duration::from_secs(1) },
        );
        for i in 0..1024u64 {
            b.push(GemmRequest::new(i, Matrix::eye(16), Matrix::eye(16)));
        }
        std::hint::black_box(b.flush(|n| n).unwrap());
    });
    println!("{}  ({:.0} req/s through the batcher)", r.report(),
             1024.0 / r.mean().as_secs_f64());

    // -- tensor conversion: Matrix -> TensorData -> literal-ready bytes
    let ms: Vec<Matrix> = (0..256).map(|_| uniform_matrix(rng, 16, 16, -1.0, 1.0)).collect();
    let r = bench("l3/tensor_from_batch_256x16x16", 500, || {
        std::hint::black_box(TensorData::from_batch(&ms).unwrap());
    });
    println!("{}", r.report());

    // -- PJRT execution reference point (what the overhead competes with)
    let mut engine = Engine::discover().unwrap();
    let a = TensorData::from_batch(&ms).unwrap();
    let name = engine.manifest().batched_at_least(256, 16).unwrap().name.clone();
    let r = bench_config("pjrt/batched_b256_reference", 20, 100, 20_000, || {
        std::hint::black_box(engine.run(&name, &[a.clone(), a.clone()]).unwrap());
    });
    println!("{}", r.report());

    println!("\ntarget (DESIGN.md §Perf): router+batcher+conversion must stay well under");
    println!("the PJRT execution time above — L3 is not allowed to be the bottleneck.");
}
