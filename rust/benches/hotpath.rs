//! Hot-path benchmarks for the §Perf optimization pass (EXPERIMENTS.md):
//! the L3 components that sit on the request path, measured in isolation
//! so the coordinator overhead can be compared against artifact
//! execution time.
//!
//! Run: `cargo bench --bench hotpath`  (needs `make artifacts`)

use std::time::Duration;

use tensoremu::coordinator::{Batcher, BatcherConfig, GemmRequest, PrecisionPolicy, Router};
use tensoremu::gemm::Matrix;
use tensoremu::runtime::{Engine, Manifest, TensorData};
use tensoremu::util::bench::{bench, bench_config};
use tensoremu::workload::{uniform_matrix, Rng};

fn main() {
    let manifest = Manifest::discover().expect("run `make artifacts` first");

    // -- router: requests/second it can classify
    let router = Router::new(manifest.clone(), 16, PrecisionPolicy::default());
    let mut rng = Rng::new(1);
    let reqs: Vec<GemmRequest> = (0..256)
        .map(|i| {
            let n = [16usize, 64, 256][i % 3];
            GemmRequest::new(i as u64, uniform_matrix(&mut rng, n, n, -1.0, 1.0),
                             uniform_matrix(&mut rng, n, n, -1.0, 1.0))
        })
        .collect();
    let r = bench("l3/router_route_256req", 200, || {
        for req in &reqs {
            std::hint::black_box(router.route(req));
        }
    });
    println!("{}  ({:.0} routes/s)", r.report(), 256.0 / r.mean().as_secs_f64());

    // -- batcher: enqueue + flush cycle
    let r = bench("l3/batcher_push_flush_1024", 100, || {
        let mut b = Batcher::new(
            16,
            BatcherConfig { max_batch: 1024, max_wait: Duration::from_secs(1) },
        );
        for i in 0..1024u64 {
            b.push(GemmRequest::new(i, Matrix::eye(16), Matrix::eye(16)));
        }
        std::hint::black_box(b.flush(|n| n).unwrap());
    });
    println!("{}  ({:.0} req/s through the batcher)", r.report(),
             1024.0 / r.mean().as_secs_f64());

    // -- tensor conversion: Matrix -> TensorData -> literal-ready bytes
    let ms: Vec<Matrix> = (0..256).map(|_| uniform_matrix(&mut rng, 16, 16, -1.0, 1.0)).collect();
    let r = bench("l3/tensor_from_batch_256x16x16", 500, || {
        std::hint::black_box(TensorData::from_batch(&ms).unwrap());
    });
    println!("{}", r.report());

    // -- PJRT execution reference point (what the overhead competes with)
    let mut engine = Engine::discover().unwrap();
    let a = TensorData::from_batch(&ms).unwrap();
    let name = engine.manifest().batched_at_least(256, 16).unwrap().name.clone();
    let r = bench_config("pjrt/batched_b256_reference", 20, 100, 20_000, || {
        std::hint::black_box(engine.run(&name, &[a.clone(), a.clone()]).unwrap());
    });
    println!("{}", r.report());

    println!("\ntarget (DESIGN.md §Perf): router+batcher+conversion must stay well under");
    println!("the PJRT execution time above — L3 is not allowed to be the bottleneck.");
}
