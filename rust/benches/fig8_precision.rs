//! Bench target for Fig. 8: measures the precision figure through real
//! PJRT executions of the error-probe artifacts (plus the time each
//! probe takes, since the probes run all five GEMM variants in-graph).
//!
//! Run: `cargo bench --bench fig8_precision`  (needs `make artifacts`)

use tensoremu::figures::fig8;
use tensoremu::runtime::{Engine, TensorData};
use tensoremu::util::bench::bench_config;
use tensoremu::workload::{uniform_matrix, Rng};

fn main() {
    let mut engine = Engine::discover().expect("run `make artifacts` first");

    let trials = std::env::var("FIG8_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let f = fig8::compute(&mut engine, trials, -1.0, 1.0, 42).unwrap();
    println!("{}", fig8::render(&f));

    // probe execution timing per size (one warm run already happened)
    let sizes = engine.manifest().errprobe_sizes();
    let mut rng = Rng::new(9);
    for n in sizes {
        let a = TensorData::from_matrix(&uniform_matrix(&mut rng, n, n, -1.0, 1.0));
        let b = TensorData::from_matrix(&uniform_matrix(&mut rng, n, n, -1.0, 1.0));
        let r = bench_config(&format!("pjrt/errprobe_n{n}"), 5, 10, 30_000, || {
            std::hint::black_box(engine.run_errprobe(n, &a, &b).unwrap());
        });
        println!("{}", r.report());
    }
}
