//! Bench target for Fig. 7: regenerates the batched-GEMM table from the
//! Volta model, then measures the *real* batched path end-to-end: the
//! batched WMMA artifact through PJRT vs per-request execution — the
//! measured analog of the paper's batched-vs-unbatched comparison.
//!
//! Run: `cargo bench --bench fig7_batched`  (needs `make artifacts`)

use tensoremu::figures::fig7;
use tensoremu::runtime::{Engine, TensorData};
use tensoremu::sim::VoltaConfig;
use tensoremu::util::bench::bench;
use tensoremu::workload::{uniform_batch, Rng};

fn main() {
    let cfg = VoltaConfig::tesla_v100_pdc();
    println!("{}", fig7::render(&fig7::compute(&cfg)));

    let Ok(mut engine) = Engine::discover() else {
        eprintln!("artifacts not found; run `make artifacts` for the measured half");
        return;
    };

    // measured: batched artifact vs one-by-one execution of the same work
    let mut rng = Rng::new(2);
    for &batch in &[64usize, 256, 1024] {
        let a = uniform_batch(&mut rng, batch, 16, -1.0, 1.0);
        let b = uniform_batch(&mut rng, batch, 16, -1.0, 1.0);
        let ta = TensorData::from_batch(&a).unwrap();
        let tb = TensorData::from_batch(&b).unwrap();
        let meta = engine.manifest().batched_at_least(batch, 16).unwrap();
        let name = meta.name.clone();
        let flops = batch as f64 * 2.0 * 16f64.powi(3);

        let r = bench(&format!("pjrt/batched_b{batch}"), 10, || {
            std::hint::black_box(engine.run(&name, &[ta.clone(), tb.clone()]).unwrap());
        });
        println!("{}  ({:.2} Gflop/s)", r.report(), r.harmonic_mean_rate(flops) / 1e9);
    }

    // under-filled baseline: four calls of the smallest batched artifact
    // (padded mostly with zeros) vs one full call — the measured value of
    // aggregation
    if let Some(meta) = engine.manifest().batched_at_least(1, 16) {
        let cap = meta.batch.unwrap();
        let name = meta.name.clone();
        let mut rng = Rng::new(3);
        let a = uniform_batch(&mut rng, cap, 16, -1.0, 1.0);
        let b = uniform_batch(&mut rng, cap, 16, -1.0, 1.0);
        let ta = TensorData::from_batch(&a).unwrap();
        let tb = TensorData::from_batch(&b).unwrap();
        let r = bench(&format!("pjrt/underfilled_b{cap}_x4_calls"), 10, || {
            for _ in 0..4 {
                std::hint::black_box(engine.run(&name, &[ta.clone(), tb.clone()]).unwrap());
            }
        });
        println!("{}  (4 dispatches = the unbatched-serving baseline)", r.report());
    }
}
